//! The step-level training engine: one [`Stepper`] drives everything that
//! happens *between* data and curve — lane-parallel token stepping, the
//! ordered gradient reduction, the optimizer updates, pruning, and the
//! snapshot/restore of the complete mutable training state.
//!
//! ## Contract
//!
//! Construct → [`step`](Stepper::step) (or
//! [`step_online`](Stepper::step_online)) → [`save_state`](Stepper::save_state)
//! / [`load_state`](Stepper::load_state):
//!
//! * **Construction** replays the historical driver order exactly — θ is
//!   initialised from the driver RNG first, then the [`LaneExecutor`] splits
//!   one lane stream per minibatch lane — so a `Stepper` built from a given
//!   `(config, rng)` is bitwise identical to the pre-split `looper` driver.
//! * **`step`** consumes one minibatch ([`StepInput`]) and performs every θ
//!   update the schedule calls for: char-LM truncation segments, Copy
//!   full-unroll, the single-worker legacy online walk, or the batched-online
//!   lockstep schedule. Returns the minibatch loss ([`StepResult`]).
//! * **`step_online`** is the serve runtime's entry: one token on each
//!   *active* lane, one shared θ update averaged over the lanes that
//!   stepped, per-lane losses reported back. Idle lanes contribute nothing
//!   (their gradient buffers are zero), so cross-**session** batches of any
//!   occupancy share one code path with training.
//! * **`save_state`/`load_state`** bridge to [`TrainCheckpoint`]: every lane's
//!   tracking blob, both optimizers, the data streams and all counters.
//!   Restores are length/structure-verified and continue bit for bit.
//!
//! The training loops (`train::looper`) and the session server
//! (`crate::serve`) are both thin orchestration over this type: feeders,
//! curves and checkpoints sit outside; the update semantics live here, once.

use crate::cells::Cell;
use crate::data::copy::CopySeq;
use crate::errors::Result;
use crate::grad::GradAlgo;
use crate::models::{Embedding, Readout, ReadoutGrad};
use crate::opt::{Adam, Optimizer};
use crate::runtime::serde::{Reader, Writer};
use crate::tensor::rng::Pcg32;
use crate::train::checkpoint::{ConfigKey, LaneCheckpoint, TrainCheckpoint};
use crate::train::config::TrainConfig;
use crate::train::executor::{LaneExecutor, LaneSlot};
use crate::train::metrics::{bpc_from_nats, CurvePoint};
use crate::train::prune::Pruner;
use std::sync::{Arc, Mutex};

/// One minibatch of task data, borrowed from the caller's feeder.
pub enum StepInput<'a> {
    /// Char-LM: one crop per lane, each `seq_len` bytes.
    CharLm { crops: &'a [Vec<u8>] },
    /// Copy task: one curriculum-sampled sequence per lane.
    Copy { seqs: &'a [CopySeq] },
}

/// What one [`Stepper::step`] reports back to the orchestration loop.
#[derive(Clone, Copy, Debug)]
pub struct StepResult {
    /// Mean minibatch loss in bits/char (NaN when no position was scored).
    pub train_bpc: f64,
    /// Σ loss nats over the minibatch (ordered per-lane drain).
    pub nll_sum: f64,
    /// Scored positions behind `nll_sum`.
    pub nll_n: u64,
}

/// Where a [`Stepper::load_state`] restore picks the training loop back up.
pub struct ResumePoint {
    pub start_step: usize,
    pub last_train_bpc: f64,
    pub last_valid_bpc: f64,
    pub curve: Vec<CurvePoint>,
}

// ---------------------------------------------------------------------------
// Lane sharding
// ---------------------------------------------------------------------------

/// One lane's gradient contribution at one update boundary, produced by the
/// shard worker that owns the lane. Exactly what the lane's local buffers
/// would hold in a single-process run — the coordinator copies it into its
/// own [`LaneSlot`] and runs the ordinary lane-order reduction, so sharding
/// reuses the arithmetic (and its bitwise-determinism guarantee) verbatim.
#[derive(Clone, Debug)]
pub struct LanePartial {
    /// Recurrent-parameter gradient accumulator (`num_params` long).
    pub g_rec: Vec<f32>,
    /// Readout gradient accumulator (flat layout).
    pub g_ro_flat: Vec<f32>,
    /// Lane-steps contributed since the previous update boundary.
    pub pending: u64,
}

/// One lane's loss/accounting report at the end of a minibatch step.
/// `nll_sum`/`nll_n` cover the step just finished (the worker zeroes them
/// after reporting, mirroring `drain_step_nll`); `tokens` and the FLOP
/// counters are absolute run totals and are assigned, not added.
#[derive(Clone, Debug)]
pub struct LaneStepStats {
    pub nll_sum: f64,
    pub nll_n: u64,
    pub tokens: u64,
    pub flops_sum: f64,
    pub flops_n: u64,
}

/// One lane's complete transferable state — the wire twin of
/// [`LaneCheckpoint`], moved between the coordinator and a worker at
/// checkpoint boundaries (pull before save, push after a resume/reshard).
#[derive(Clone, Debug)]
pub struct LaneState {
    /// Opaque [`GradAlgo::save_state`] blob.
    pub algo: Vec<u8>,
    /// The slot's `Pcg32` stream (`state`, `inc`).
    pub rng: (u64, u64),
    pub tokens: u64,
    pub flops_sum: f64,
    pub flops_n: u64,
}

/// The coordinator side of a lane-sharded run. An implementation (the
/// socket-backed one lives in `crate::shard`) fans each request out to the
/// worker processes owning the lanes and returns the per-lane results **in
/// lane order** across all workers. The [`Stepper`] stays the single owner
/// of θ, the readout and both optimizers; a backend only moves data.
pub trait ShardBackend {
    /// Advance every lane through crop positions `t0..t1` and return each
    /// lane's flushed gradient contribution.
    fn charlm_segment(
        &mut self,
        crops: &[Vec<u8>],
        t0: usize,
        t1: usize,
    ) -> Result<Vec<LanePartial>>;

    /// Full-unroll Copy minibatch: each lane consumes its whole sequence;
    /// one gradient contribution per lane.
    fn copy_step(&mut self, seqs: &[CopySeq]) -> Result<Vec<LanePartial>>;

    /// Per-lane loss/accounting for the minibatch step just finished.
    fn step_stats(&mut self) -> Result<Vec<LaneStepStats>>;

    /// Ship the post-update shared weights to every worker.
    fn broadcast_shared(&mut self, theta: &[f32], readout_flat: &[f32]) -> Result<()>;

    /// Collect every lane's tracking state (checkpoint boundary).
    fn pull_lane_states(&mut self) -> Result<Vec<LaneState>>;

    /// Install lane states + shared weights on the workers (resume/reshard).
    fn push_lane_states(
        &mut self,
        states: &[LaneState],
        theta: &[f32],
        readout_flat: &[f32],
    ) -> Result<()>;
}

/// The step-level training engine. See the module docs for the contract.
pub struct Stepper<'c> {
    cell: &'c dyn Cell,
    embed: Embedding,
    readout: Readout,
    theta: Vec<f32>,
    exec: LaneExecutor<'c>,
    /// Clones of the per-lane RNGs taken right after construction, advanced
    /// only by data sampling (the feeder draws from them in lane order).
    /// Behind a mutex so checkpoints can snapshot them at quiescent step
    /// boundaries; the lock is taken once per batch, never per token.
    data_streams: Arc<Mutex<Vec<Pcg32>>>,
    g_rec: Vec<f32>,
    g_ro: ReadoutGrad,
    opt_rec: Adam,
    opt_ro: Adam,
    pruner: Option<Pruner>,
    opt_steps: u64,
    trains_rec: bool,
    seq_len: usize,
    truncation: usize,
    /// `Some` when lane computation is sharded across worker processes. The
    /// local slots then act as state mirrors: gradients arrive as
    /// [`LanePartial`]s, tracking state is refreshed from the workers at
    /// checkpoint boundaries ([`sync_lanes_from_backend`](Self::sync_lanes_from_backend)).
    backend: Option<Box<dyn ShardBackend>>,
}

impl<'c> Stepper<'c> {
    /// Build the engine for `cfg`. RNG protocol (bitwise-stability
    /// contract): θ initialises from `rng` first, then the executor splits
    /// one lane stream per lane — exactly the historical driver order, so
    /// every existing seed reproduces its old run.
    pub fn new(
        cfg: &TrainConfig,
        cell: &'c dyn Cell,
        embed: Embedding,
        readout: Readout,
        rng: &mut Pcg32,
    ) -> Stepper<'c> {
        let p = cell.num_params();
        let theta = cell.init_params(rng);
        let exec = LaneExecutor::with_mode(
            cell,
            cfg.method,
            &readout,
            cfg.batch.max(1),
            cfg.workers,
            cfg.spawn,
            cfg.kernel.resolve_logged("stepper"),
            rng,
        );
        let data_streams: Arc<Mutex<Vec<Pcg32>>> =
            Arc::new(Mutex::new(exec.slots().iter().map(|s| s.rng.clone()).collect()));
        let g_ro = readout.make_grad();
        let opt_ro = Adam::new(readout.num_params(), cfg.lr);
        let pruner = cfg.prune_to.map(|s| {
            Pruner::new(
                cell.param_info(),
                s,
                0,
                cfg.prune_end_step.min(cfg.steps as u64),
                cfg.prune_every,
            )
        });
        Stepper {
            cell,
            embed,
            readout,
            theta,
            exec,
            data_streams,
            g_rec: vec![0.0f32; p],
            g_ro,
            opt_rec: Adam::new(p, cfg.lr),
            opt_ro,
            pruner,
            opt_steps: 0,
            trains_rec: cfg.method.trains_recurrent(),
            seq_len: cfg.seq_len,
            truncation: cfg.truncation,
            backend: None,
        }
    }

    /// Attach a shard backend: every subsequent [`step`](Self::step) fans the
    /// lane computation out through it instead of the local executor.
    pub fn set_backend(&mut self, backend: Box<dyn ShardBackend>) {
        self.backend = Some(backend);
    }

    pub fn has_backend(&self) -> bool {
        self.backend.is_some()
    }

    // --- accessors -------------------------------------------------------

    /// The shared cell (borrowed for `'c`, so the reference outlives `self`).
    pub fn cell(&self) -> &'c dyn Cell {
        self.cell
    }

    pub fn theta(&self) -> &[f32] {
        &self.theta
    }

    pub fn embed(&self) -> &Embedding {
        &self.embed
    }

    pub fn readout(&self) -> &Readout {
        &self.readout
    }

    /// The data streams the feeder samples from (see field docs).
    pub fn data_streams(&self) -> &Arc<Mutex<Vec<Pcg32>>> {
        &self.data_streams
    }

    pub fn lanes(&self) -> usize {
        self.exec.lanes()
    }

    pub fn opt_steps(&self) -> u64 {
        self.opt_steps
    }

    pub fn tokens_seen(&self) -> u64 {
        self.exec.tokens_seen()
    }

    pub fn tracking_flops_mean(&self) -> f64 {
        self.exec.tracking_flops_mean()
    }

    pub fn tracking_memory_floats(&self) -> usize {
        self.exec.tracking_memory_floats()
    }

    /// Swap a caller-owned algorithm box into lane `lane` (and the lane's
    /// previous occupant out into `algo`). This is the serve runtime's
    /// session↔lane seam: a resident session's tracking state steps through
    /// the executor without a copy, and two swaps return it.
    pub fn swap_lane_algo(&mut self, lane: usize, algo: &mut Box<dyn GradAlgo + 'c>) {
        std::mem::swap(&mut self.exec.slot_mut(lane).algo, algo);
    }

    // --- training steps --------------------------------------------------

    /// One full minibatch step: every token of `input` is consumed and every
    /// θ update the schedule calls for is applied. Returns the minibatch
    /// loss (ordered per-lane drain, so the mean — and anything fed from it,
    /// like the Copy curriculum — is worker-count independent). Only a shard
    /// backend can fail here: the local paths are infallible.
    pub fn step(&mut self, input: StepInput<'_>) -> Result<StepResult> {
        if let Some(mut backend) = self.backend.take() {
            let stepped = match input {
                StepInput::CharLm { crops } => self.step_charlm_sharded(&mut *backend, crops),
                StepInput::Copy { seqs } => self.step_copy_sharded(&mut *backend, seqs),
            };
            self.backend = Some(backend);
            stepped?;
        } else {
            match input {
                StepInput::CharLm { crops } => self.step_charlm(crops),
                StepInput::Copy { seqs } => self.step_copy(seqs),
            }
        }
        let (nll_sum, nll_n) = self.exec.drain_step_nll();
        let mean = if nll_n == 0 { f64::NAN } else { nll_sum / nll_n as f64 };
        Ok(StepResult { train_bpc: bpc_from_nats(mean), nll_sum, nll_n })
    }

    /// B independent crops, one per lane, advanced in lockstep segments of
    /// `truncation` tokens (whole crop when 0); θ updates at every segment
    /// boundary.
    fn step_charlm(&mut self, crops: &[Vec<u8>]) {
        self.exec.reset_lanes();
        let seg = if self.truncation == 0 { self.seq_len } else { self.truncation };
        let mut t0 = 0usize;
        while t0 < self.seq_len {
            let t1 = (t0 + seg).min(self.seq_len);
            {
                let theta_ref: &[f32] = &self.theta;
                let embed = &self.embed;
                let ro: &Readout = &self.readout;
                let trains_rec = self.trains_rec;
                self.exec.for_each_lane(|i, slot| {
                    let crop = &crops[i];
                    for t in t0..t1 {
                        lane_step_charlm(slot, theta_ref, embed, ro, crop, t, trains_rec);
                    }
                    // Segment end is an update boundary: materialize
                    // deferred (BPTT) gradients in-lane, in parallel.
                    slot.algo.flush(theta_ref, &mut slot.g_rec);
                });
            }
            self.reduce();
            t0 = t1;
        }
    }

    /// The Copy task's three schedules (full unroll / legacy single-worker
    /// online walk / batched-online lockstep) — see the looper module docs
    /// for why the single-worker walk is preserved verbatim.
    fn step_copy(&mut self, seqs: &[CopySeq]) {
        self.exec.reset_lanes();
        if self.truncation == 0 {
            // Full unroll: lanes are fully independent work items — lengths
            // vary, so hand them out by work stealing; one shared update at
            // the minibatch boundary.
            {
                let theta_ref: &[f32] = &self.theta;
                let embed = &self.embed;
                let ro: &Readout = &self.readout;
                let trains_rec = self.trains_rec;
                self.exec.for_each_lane_stealing(|i, slot| {
                    let seq = &seqs[i];
                    for (t, &tok) in seq.inputs.iter().enumerate() {
                        lane_step_copy(
                            slot, theta_ref, embed, ro, tok, seq.targets[t], trains_rec,
                        );
                    }
                    slot.algo.flush(theta_ref, &mut slot.g_rec);
                });
            }
            self.reduce();
        } else if self.exec.workers() <= 1 {
            // Legacy fully-online schedule (identical to the sequential
            // engine): walk the lanes one after another, updating θ every
            // `truncation` lane-tokens.
            let mut window = 0usize;
            for i in 0..self.exec.lanes() {
                let seq = &seqs[i];
                for (t, &tok) in seq.inputs.iter().enumerate() {
                    lane_step_copy(
                        self.exec.slot_mut(i),
                        &self.theta,
                        &self.embed,
                        &self.readout,
                        tok,
                        seq.targets[t],
                        self.trains_rec,
                    );
                    window += 1;
                    if window >= self.truncation {
                        self.exec.flush_all(&self.theta);
                        self.reduce();
                        window = 0;
                    }
                }
            }
            if self.exec.total_pending() > 0 {
                self.exec.flush_all(&self.theta);
                self.reduce();
            }
        } else {
            // Batched-online: all still-active lanes advance in lockstep; θ
            // updates every `truncation` global timesteps with gradients
            // averaged across the lanes that contributed. Deterministic for
            // any worker count.
            let max_len = seqs.iter().map(|s| s.inputs.len()).max().unwrap_or(0);
            let mut t0 = 0usize;
            while t0 < max_len {
                let t1 = (t0 + self.truncation).min(max_len);
                {
                    let theta_ref: &[f32] = &self.theta;
                    let embed = &self.embed;
                    let ro: &Readout = &self.readout;
                    let trains_rec = self.trains_rec;
                    self.exec.for_each_lane(|i, slot| {
                        let seq = &seqs[i];
                        let hi = t1.min(seq.inputs.len());
                        for t in t0..hi {
                            lane_step_copy(
                                slot, theta_ref, embed, ro, seq.inputs[t], seq.targets[t],
                                trains_rec,
                            );
                        }
                        if t0 < hi {
                            slot.algo.flush(theta_ref, &mut slot.g_rec);
                        }
                    });
                }
                self.reduce();
                t0 = t1;
            }
        }
    }

    /// One fully-online cross-session tick: each lane with `Some((input,
    /// target))` steps one byte transition and flushes; idle lanes are
    /// untouched. Then one shared θ update, averaged over the lanes that
    /// stepped (zero-pending lanes contribute zero gradient). Per-lane
    /// losses (nats) are drained into `nll_out` in lane order. No
    /// `reset_lanes`: sessions are endless streams, their recurrent state
    /// carries across ticks.
    ///
    /// With *no* active lane the update is skipped entirely — Adam's moment
    /// decay must not drift θ while every session is idle.
    pub fn step_online(&mut self, tokens: &[Option<(u8, u8)>], nll_out: &mut [f64]) {
        debug_assert_eq!(tokens.len(), self.exec.lanes());
        debug_assert_eq!(nll_out.len(), self.exec.lanes());
        {
            let theta_ref: &[f32] = &self.theta;
            let embed = &self.embed;
            let ro: &Readout = &self.readout;
            let trains_rec = self.trains_rec;
            self.exec.for_each_lane(|i, slot| {
                let Some((x, y)) = tokens[i] else { return };
                // audit: hot-path
                {
                    lane_step_pair(slot, theta_ref, embed, ro, x, y, trains_rec);
                    slot.algo.flush(theta_ref, &mut slot.g_rec);
                }
            });
        }
        if self.exec.total_pending() > 0 {
            self.reduce();
        }
        for (out, slot) in nll_out.iter_mut().zip(self.exec.slots_mut().iter_mut()) {
            *out = slot.nll_sum;
            slot.nll_sum = 0.0;
            slot.nll_n = 0;
        }
    }

    // --- sharded steps ---------------------------------------------------

    /// Char-LM step with the lane computation on remote workers. Same
    /// segment schedule as [`step_charlm`](Self::step_charlm); each segment
    /// boundary pulls per-lane partials, runs the **local** lane-order
    /// reduction, and broadcasts the updated shared weights. The local
    /// slots' tracking state is not advanced here — it is refreshed from
    /// the workers at checkpoint boundaries.
    fn step_charlm_sharded(
        &mut self,
        backend: &mut dyn ShardBackend,
        crops: &[Vec<u8>],
    ) -> Result<()> {
        let seg = if self.truncation == 0 { self.seq_len } else { self.truncation };
        let mut t0 = 0usize;
        while t0 < self.seq_len {
            let t1 = (t0 + seg).min(self.seq_len);
            let partials = backend.charlm_segment(crops, t0, t1)?;
            self.install_partials(&partials)?;
            self.reduce();
            backend.broadcast_shared(&self.theta, &self.readout.params_flat())?;
            t0 = t1;
        }
        self.install_stats(&backend.step_stats()?)
    }

    /// Copy-task step on remote workers. Only the full-unroll schedule
    /// (`truncation == 0`) shards: it has exactly one update boundary per
    /// minibatch. The truncated schedules update θ mid-sequence — the
    /// legacy single-worker walk serially across lanes — so sharding them
    /// is refused with a named error rather than silently retrained under
    /// different semantics.
    fn step_copy_sharded(
        &mut self,
        backend: &mut dyn ShardBackend,
        seqs: &[CopySeq],
    ) -> Result<()> {
        crate::ensure!(
            self.truncation == 0,
            "lane sharding supports the Copy task only with --trunc 0 (full unroll); \
             truncated Copy schedules update θ mid-sequence and are not shardable"
        );
        let partials = backend.copy_step(seqs)?;
        self.install_partials(&partials)?;
        self.reduce();
        backend.broadcast_shared(&self.theta, &self.readout.params_flat())?;
        self.install_stats(&backend.step_stats()?)
    }

    /// Copy worker-computed gradient contributions into the local lane
    /// slots, in lane order, exactly where the local parallel sections
    /// would have left them.
    fn install_partials(&mut self, partials: &[LanePartial]) -> Result<()> {
        crate::ensure!(
            partials.len() == self.exec.lanes(),
            "shard backend returned {} lane partials for {} lanes",
            partials.len(),
            self.exec.lanes()
        );
        for (i, (slot, p)) in self.exec.slots_mut().iter_mut().zip(partials).enumerate() {
            crate::ensure!(
                p.g_rec.len() == slot.g_rec.len(),
                "lane {i}: worker sent a {}-element recurrent gradient, expected {}",
                p.g_rec.len(),
                slot.g_rec.len()
            );
            crate::ensure!(
                p.g_ro_flat.len() == slot.g_ro.flat.len(),
                "lane {i}: worker sent a {}-element readout gradient, expected {}",
                p.g_ro_flat.len(),
                slot.g_ro.flat.len()
            );
            slot.g_rec.copy_from_slice(&p.g_rec);
            slot.g_ro.flat.copy_from_slice(&p.g_ro_flat);
            slot.pending = p.pending as usize;
        }
        Ok(())
    }

    /// Install per-lane loss/accounting reports (see [`LaneStepStats`] for
    /// the assign-vs-accumulate semantics).
    fn install_stats(&mut self, stats: &[LaneStepStats]) -> Result<()> {
        crate::ensure!(
            stats.len() == self.exec.lanes(),
            "shard backend returned {} lane stats for {} lanes",
            stats.len(),
            self.exec.lanes()
        );
        for (slot, st) in self.exec.slots_mut().iter_mut().zip(stats) {
            slot.nll_sum = st.nll_sum;
            slot.nll_n = st.nll_n;
            slot.tokens = st.tokens;
            slot.flops_sum = st.flops_sum;
            slot.flops_n = st.flops_n;
        }
        Ok(())
    }

    /// Refresh the local lane mirrors from the workers — tracking blobs,
    /// slot RNGs and counters. The looper calls this right before
    /// [`save_state`](Self::save_state) on sharded runs, making the
    /// assembled checkpoint identical to a single-process run's. No-op
    /// without a backend.
    pub fn sync_lanes_from_backend(&mut self) -> Result<()> {
        let Some(mut backend) = self.backend.take() else { return Ok(()) };
        let synced = self.sync_lanes_inner(&mut *backend);
        self.backend = Some(backend);
        synced
    }

    fn sync_lanes_inner(&mut self, backend: &mut dyn ShardBackend) -> Result<()> {
        let states = backend.pull_lane_states()?;
        crate::ensure!(
            states.len() == self.exec.lanes(),
            "shard backend returned {} lane states for {} lanes",
            states.len(),
            self.exec.lanes()
        );
        for (i, (slot, st)) in self.exec.slots_mut().iter_mut().zip(&states).enumerate() {
            slot.rng = Pcg32::from_parts(st.rng.0, st.rng.1);
            slot.tokens = st.tokens;
            slot.flops_sum = st.flops_sum;
            slot.flops_n = st.flops_n;
            slot.algo.load_state(&mut Reader::new(&st.algo)).map_err(|e| {
                e.context(format!("installing lane {i} tracking state from its shard worker"))
            })?;
        }
        Ok(())
    }

    /// Ship the local lane state (typically just restored by
    /// [`load_state`](Self::load_state)) plus the shared weights to the
    /// workers — the second half of an elastic reshard: any lane→process
    /// mapping receives exactly the states the checkpoint holds. No-op
    /// without a backend. A **fresh** sharded start needs no push: workers
    /// replay the deterministic construction and are already identical.
    pub fn push_lanes_to_backend(&mut self) -> Result<()> {
        let Some(mut backend) = self.backend.take() else { return Ok(()) };
        let states: Vec<LaneState> = self
            .exec
            .slots()
            .iter()
            .map(|s| {
                let mut w = Writer::new();
                s.algo.save_state(&mut w);
                LaneState {
                    algo: w.into_bytes(),
                    rng: s.rng.state_parts(),
                    tokens: s.tokens,
                    flops_sum: s.flops_sum,
                    flops_n: s.flops_n,
                }
            })
            .collect();
        let pushed =
            backend.push_lane_states(&states, &self.theta, &self.readout.params_flat());
        self.backend = Some(backend);
        pushed
    }

    /// Ordered reduction + shared weight update (see
    /// [`LaneExecutor::reduce_and_update`]).
    fn reduce(&mut self) {
        self.exec.reduce_and_update(
            &mut self.theta,
            &mut self.g_rec,
            &mut self.readout,
            &mut self.g_ro,
            &mut self.opt_rec,
            &mut self.opt_ro,
            &mut self.pruner,
            &mut self.opt_steps,
            self.trains_rec,
        );
    }

    // --- snapshot / restore ----------------------------------------------

    /// Assemble a [`TrainCheckpoint`] from the live state. Read-only:
    /// snapshotting draws from no RNG and mutates nothing, so a checkpointed
    /// run is bitwise identical to an uncheckpointed one. Must be called at
    /// a step boundary with the data streams quiescent (the looper defers
    /// the next prefetch request for exactly this reason).
    #[allow(clippy::too_many_arguments)]
    pub fn save_state(
        &self,
        key: &ConfigKey,
        next_step: u64,
        curriculum_level: u64,
        last_train_bpc: f64,
        last_valid_bpc: f64,
        driver_rng: &Pcg32,
        curve: &[CurvePoint],
    ) -> TrainCheckpoint {
        let mut w = Writer::new();
        self.opt_rec.save_state(&mut w);
        let opt_rec_blob = w.into_bytes();
        let mut w = Writer::new();
        self.opt_ro.save_state(&mut w);
        let opt_ro_blob = w.into_bytes();
        let data_rngs: Vec<(u64, u64)> = self
            .data_streams
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .iter()
            .map(|r| r.state_parts())
            .collect();
        let lanes: Vec<LaneCheckpoint> = self
            .exec
            .slots()
            .iter()
            .map(|s| {
                let mut w = Writer::new();
                s.algo.save_state(&mut w);
                LaneCheckpoint {
                    rng: s.rng.state_parts(),
                    tokens: s.tokens,
                    flops_sum: s.flops_sum,
                    flops_n: s.flops_n,
                    algo: w.into_bytes(),
                }
            })
            .collect();
        TrainCheckpoint {
            key: key.clone(),
            next_step,
            opt_steps: self.opt_steps,
            curriculum_level,
            last_train_bpc,
            last_valid_bpc,
            theta: self.theta.clone(),
            readout: self.readout.params_flat(),
            opt_rec: opt_rec_blob,
            opt_ro: opt_ro_blob,
            driver_rng: driver_rng.state_parts(),
            data_rngs,
            lanes,
            pruner_keep: self.pruner.as_ref().map(|p| p.keep_mask().to_vec()),
            curve: curve.to_vec(),
        }
    }

    /// Graft a [`TrainCheckpoint`] onto the freshly (re)built engine. The
    /// rebuild itself is deterministic from the config (cell masks,
    /// embedding, shapes), the key check proves the config matches, and
    /// every restored piece is length/structure-verified — after this the
    /// next step continues bit for bit. `driver_rng` and `curriculum` are
    /// the two pieces of loop state living outside the engine.
    pub fn load_state(
        &mut self,
        ck: TrainCheckpoint,
        key: &ConfigKey,
        driver_rng: &mut Pcg32,
        curriculum: &mut crate::data::copy::Curriculum,
    ) -> Result<ResumePoint> {
        ck.key.ensure_matches(key)?;
        crate::ensure!(
            ck.theta.len() == self.theta.len(),
            "θ length mismatch: checkpoint {} vs run {}",
            ck.theta.len(),
            self.theta.len()
        );
        self.theta.copy_from_slice(&ck.theta);
        crate::ensure!(
            ck.readout.len() == self.readout.num_params(),
            "readout length mismatch: checkpoint {} vs run {}",
            ck.readout.len(),
            self.readout.num_params()
        );
        self.readout.set_params(&ck.readout);
        self.opt_rec
            .load_state(&mut Reader::new(&ck.opt_rec))
            .map_err(|e| e.context("restoring the recurrent optimizer"))?;
        self.opt_ro
            .load_state(&mut Reader::new(&ck.opt_ro))
            .map_err(|e| e.context("restoring the readout optimizer"))?;
        *driver_rng = Pcg32::from_parts(ck.driver_rng.0, ck.driver_rng.1);
        {
            let mut streams = self.data_streams.lock().unwrap_or_else(|e| e.into_inner());
            crate::ensure!(
                ck.data_rngs.len() == streams.len(),
                "data-stream count mismatch: checkpoint {} vs run {} lanes",
                ck.data_rngs.len(),
                streams.len()
            );
            for (s, &(state, inc)) in streams.iter_mut().zip(&ck.data_rngs) {
                *s = Pcg32::from_parts(state, inc);
            }
        }
        crate::ensure!(
            ck.lanes.len() == self.exec.lanes(),
            "lane count mismatch: checkpoint {} vs run {}",
            ck.lanes.len(),
            self.exec.lanes()
        );
        for (i, (slot, lane)) in self.exec.slots_mut().iter_mut().zip(&ck.lanes).enumerate() {
            slot.rng = Pcg32::from_parts(lane.rng.0, lane.rng.1);
            slot.tokens = lane.tokens;
            slot.flops_sum = lane.flops_sum;
            slot.flops_n = lane.flops_n;
            slot.algo
                .load_state(&mut Reader::new(&lane.algo))
                .map_err(|e| e.context(format!("restoring lane {i} tracking state")))?;
        }
        match (self.pruner.as_mut(), &ck.pruner_keep) {
            (Some(p), Some(keep)) => p.set_keep_mask(keep)?,
            (None, None) => {}
            (have, _) => crate::bail!(
                "pruning configuration mismatch: checkpoint {} a pruner mask, this run {}",
                if ck.pruner_keep.is_some() { "has" } else { "lacks" },
                if have.is_some() { "prunes" } else { "does not prune" }
            ),
        }
        curriculum.set_level(ck.curriculum_level as usize);
        self.opt_steps = ck.opt_steps;
        Ok(ResumePoint {
            start_step: ck.next_step as usize,
            last_train_bpc: ck.last_train_bpc,
            last_valid_bpc: ck.last_valid_bpc,
            curve: ck.curve,
        })
    }

    /// Serialize the *shared* training state — θ, readout, both optimizers,
    /// the optimizer step count — into `w`. The serve runtime embeds this in
    /// its server checkpoint next to the per-session blobs (sessions own the
    /// per-lane tracking state there, so [`save_state`](Self::save_state)'s
    /// lane section does not apply).
    pub fn save_shared(&self, w: &mut Writer) {
        w.put_f32s(&self.theta);
        w.put_f32s(&self.readout.params_flat());
        let mut ow = Writer::new();
        self.opt_rec.save_state(&mut ow);
        w.put_bytes(&ow.into_bytes());
        let mut ow = Writer::new();
        self.opt_ro.save_state(&mut ow);
        w.put_bytes(&ow.into_bytes());
        w.put_u64(self.opt_steps);
    }

    /// Restore a [`save_shared`](Self::save_shared) snapshot; the inverse
    /// length/structure checks of [`load_state`](Self::load_state) apply.
    pub fn load_shared(&mut self, r: &mut Reader<'_>) -> Result<()> {
        let theta = r.get_f32s()?;
        crate::ensure!(
            theta.len() == self.theta.len(),
            "θ length mismatch: snapshot {} vs run {}",
            theta.len(),
            self.theta.len()
        );
        self.theta.copy_from_slice(&theta);
        let ro = r.get_f32s()?;
        crate::ensure!(
            ro.len() == self.readout.num_params(),
            "readout length mismatch: snapshot {} vs run {}",
            ro.len(),
            self.readout.num_params()
        );
        self.readout.set_params(&ro);
        let blob = r.get_bytes()?;
        self.opt_rec
            .load_state(&mut Reader::new(&blob))
            .map_err(|e| e.context("restoring the recurrent optimizer"))?;
        let blob = r.get_bytes()?;
        self.opt_ro
            .load_state(&mut Reader::new(&blob))
            .map_err(|e| e.context("restoring the readout optimizer"))?;
        self.opt_steps = r.get_u64()?;
        Ok(())
    }
}

/// One char-LM lane-token: step the cell, read out, backprop the loss into
/// the lane's buffers. Runs inside a parallel section — touches only `slot`
/// plus shared read-only state.
pub(crate) fn lane_step_charlm(
    slot: &mut LaneSlot<'_>,
    theta: &[f32],
    embed: &Embedding,
    readout: &Readout,
    crop: &[u8],
    t: usize,
    trains_recurrent: bool,
) {
    let x = embed.lookup(crop[t] as usize);
    slot.algo.step(theta, x);
    readout.forward(slot.algo.hidden(), &mut slot.cache);
    let (nll, dh) =
        readout.loss_and_backward(&mut slot.cache, crop[t + 1] as usize, &mut slot.g_ro);
    if trains_recurrent {
        slot.algo.inject_loss(dh, &mut slot.g_rec);
    }
    slot.nll_sum += nll as f64;
    slot.nll_n += 1;
    slot.flops_sum += slot.algo.tracking_flops_per_step() as f64;
    slot.flops_n += 1;
    slot.tokens += 1;
    slot.pending += 1;
}

/// One Copy-task lane-token (loss only on prediction positions).
pub(crate) fn lane_step_copy(
    slot: &mut LaneSlot<'_>,
    theta: &[f32],
    embed: &Embedding,
    readout: &Readout,
    tok: usize,
    target: Option<usize>,
    trains_recurrent: bool,
) {
    slot.algo.step(theta, embed.lookup(tok));
    if let Some(target) = target {
        readout.forward(slot.algo.hidden(), &mut slot.cache);
        let (nll, dh) = readout.loss_and_backward(&mut slot.cache, target, &mut slot.g_ro);
        if trains_recurrent {
            slot.algo.inject_loss(dh, &mut slot.g_rec);
        }
        slot.nll_sum += nll as f64;
        slot.nll_n += 1;
    }
    slot.flops_sum += slot.algo.tracking_flops_per_step() as f64;
    slot.flops_n += 1;
    slot.tokens += 1;
    slot.pending += 1;
}

/// One serve-session byte transition: the char-LM lane step specialised to a
/// single `(input, target)` pair.
fn lane_step_pair(
    slot: &mut LaneSlot<'_>,
    theta: &[f32],
    embed: &Embedding,
    readout: &Readout,
    x: u8,
    target: u8,
    trains_recurrent: bool,
) {
    let xe = embed.lookup(x as usize);
    slot.algo.step(theta, xe);
    readout.forward(slot.algo.hidden(), &mut slot.cache);
    let (nll, dh) =
        readout.loss_and_backward(&mut slot.cache, target as usize, &mut slot.g_ro);
    if trains_recurrent {
        slot.algo.inject_loss(dh, &mut slot.g_rec);
    }
    slot.nll_sum += nll as f64;
    slot.nll_n += 1;
    slot.flops_sum += slot.algo.tracking_flops_per_step() as f64;
    slot.flops_n += 1;
    slot.tokens += 1;
    slot.pending += 1;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grad::Method;

    fn make_stepper(cfg: &TrainConfig) -> (Box<dyn crate::cells::Cell>, Pcg32) {
        let mut rng = Pcg32::seeded(cfg.seed);
        let cell = cfg.arch.build(cfg.k, cfg.embed_dim, cfg.density, &mut rng);
        (cell, rng)
    }

    #[test]
    fn step_online_idle_tick_leaves_theta_untouched() {
        let cfg = TrainConfig {
            k: 8,
            batch: 2,
            embed_dim: 4,
            readout_hidden: 8,
            method: Method::Snap(1),
            ..Default::default()
        };
        let (cell, mut rng) = make_stepper(&cfg);
        let embed = Embedding::new(256, cfg.embed_dim, &mut rng);
        let readout = Readout::new(cell.hidden_size(), cfg.readout_hidden, 256, &mut rng);
        let mut st = Stepper::new(&cfg, cell.as_ref(), embed, readout, &mut rng);
        let mut nll = vec![0.0f64; st.lanes()];
        // One real tick so the optimizer moments are nonzero.
        st.step_online(&[Some((b'a', b'b')), Some((b'c', b'd'))], &mut nll);
        let before = st.theta().to_vec();
        let steps_before = st.opt_steps();
        st.step_online(&[None, None], &mut nll);
        assert_eq!(st.opt_steps(), steps_before, "idle tick must not run the optimizer");
        for (a, b) in before.iter().zip(st.theta()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn step_online_partial_batch_only_charges_active_lanes() {
        let cfg = TrainConfig {
            k: 8,
            batch: 3,
            embed_dim: 4,
            readout_hidden: 8,
            ..Default::default()
        };
        let (cell, mut rng) = make_stepper(&cfg);
        let embed = Embedding::new(256, cfg.embed_dim, &mut rng);
        let readout = Readout::new(cell.hidden_size(), cfg.readout_hidden, 256, &mut rng);
        let mut st = Stepper::new(&cfg, cell.as_ref(), embed, readout, &mut rng);
        let mut nll = vec![0.0f64; st.lanes()];
        st.step_online(&[Some((b'x', b'y')), None, Some((b'y', b'z'))], &mut nll);
        assert!(nll[0] > 0.0);
        assert_eq!(nll[1], 0.0, "idle lane must report zero loss");
        assert!(nll[2] > 0.0);
        assert_eq!(st.tokens_seen(), 2);
        assert_eq!(st.opt_steps(), 1);
    }

    #[test]
    fn shared_state_round_trips_bitwise() {
        let cfg = TrainConfig {
            k: 8,
            batch: 2,
            embed_dim: 4,
            readout_hidden: 8,
            ..Default::default()
        };
        let (cell, mut rng) = make_stepper(&cfg);
        let embed = Embedding::new(256, cfg.embed_dim, &mut rng);
        let readout = Readout::new(cell.hidden_size(), cfg.readout_hidden, 256, &mut rng);
        let mut st = Stepper::new(&cfg, cell.as_ref(), embed, readout, &mut rng);
        let mut nll = vec![0.0f64; st.lanes()];
        for t in 0..5u8 {
            st.step_online(&[Some((t, t + 1)), Some((t + 2, t + 3))], &mut nll);
        }
        let mut w = Writer::new();
        st.save_shared(&mut w);
        let blob = w.into_bytes();

        // A freshly built engine restores to the same shared state.
        let (cell2, mut rng2) = make_stepper(&cfg);
        let embed2 = Embedding::new(256, cfg.embed_dim, &mut rng2);
        let readout2 = Readout::new(cell2.hidden_size(), cfg.readout_hidden, 256, &mut rng2);
        let mut st2 = Stepper::new(&cfg, cell2.as_ref(), embed2, readout2, &mut rng2);
        st2.load_shared(&mut Reader::new(&blob)).unwrap();
        assert_eq!(st2.opt_steps(), st.opt_steps());
        for (a, b) in st.theta().iter().zip(st2.theta()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let mut w2 = Writer::new();
        st2.save_shared(&mut w2);
        assert_eq!(blob, w2.into_bytes(), "shared snapshot must round-trip bitwise");
    }
}
