//! Readout stack: one-hidden-layer MLP + softmax (paper §5.1.1: "a one-layer
//! readout MLP mapping to 1024 hidden units before the final 256-unit
//! softmax layer").
//!
//! The readout has no recurrence, so it is trained with plain backprop at
//! every step regardless of which RTRL approximation handles the recurrent
//! core. `backward` returns both the readout parameter gradients and
//! `∂L/∂h` — the cotangent the recurrent algorithms consume.
//!
//! Concurrency contract: the forward/backward pair is split from parameter
//! mutation. `forward`, `loss_and_backward` and `backward` take `&self` and
//! write only into caller-owned [`ReadoutCache`]/[`ReadoutGrad`] buffers, so
//! N training lanes share one `&Readout` across threads (`Readout: Sync`),
//! each with its own cache and gradient buffer. Parameters change only
//! through `apply_delta`/`set_params` (`&mut self`), which the executor
//! calls between parallel sections after an ordered reduction of the
//! per-lane [`ReadoutGrad`]s.
//!
//! Perf contract: the per-token path is **allocation-free** after the first
//! call — every intermediate (activations, softmax gradient, backward
//! cotangents including the returned `∂L/∂h`) lives in the lane's
//! [`ReadoutCache`], sized on first use and reused thereafter; the dense
//! products go through `matvec_into`/`matvec_t_into`.

use crate::tensor::matrix::Matrix;
use crate::tensor::ops::{axpy_slice, drelu, log_softmax, matvec_into, matvec_t_into};
use crate::tensor::rng::Pcg32;

pub struct Readout {
    pub in_dim: usize,
    pub hidden: usize,
    pub out_dim: usize,
    /// W1: hidden × in, b1: hidden, W2: out × hidden, b2: out
    w1: Matrix,
    b1: Vec<f32>,
    w2: Matrix,
    b2: Vec<f32>,
}

/// Forward cache + backward scratch for one lane. All buffers are sized on
/// first use and reused — one `ReadoutCache` per lane makes the whole
/// per-token readout path allocation-free.
#[derive(Clone, Default)]
pub struct ReadoutCache {
    h_in: Vec<f32>,
    pre1: Vec<f32>,
    act1: Vec<f32>,
    pub logits: Vec<f32>,
    /// softmax / arbitrary logit cotangent (backward scratch)
    dlogits: Vec<f32>,
    /// relu-gated hidden cotangent (backward scratch)
    dact1: Vec<f32>,
    /// `∂L/∂h` — the value `backward` returns a borrow of
    dh: Vec<f32>,
}

/// Flat gradient buffer with the same layout as `Readout::num_params`.
pub struct ReadoutGrad {
    pub flat: Vec<f32>,
}

impl ReadoutGrad {
    /// Ordered-reduction helper: `self += other`. The lane executor folds
    /// per-lane buffers in lane order so the sum is identical for any
    /// worker count (f32 addition is not associative).
    pub fn accumulate_from(&mut self, other: &ReadoutGrad) {
        debug_assert_eq!(self.flat.len(), other.flat.len());
        for (a, b) in self.flat.iter_mut().zip(&other.flat) {
            *a += *b;
        }
    }

    /// Zero the buffer (after its contribution has been consumed).
    pub fn clear(&mut self) {
        self.flat.iter_mut().for_each(|v| *v = 0.0);
    }
}

impl Readout {
    pub fn new(in_dim: usize, hidden: usize, out_dim: usize, rng: &mut Pcg32) -> Self {
        let bound1 = (1.0 / (in_dim as f64).sqrt()) as f32;
        let bound2 = (1.0 / (hidden as f64).sqrt()) as f32;
        Readout {
            in_dim,
            hidden,
            out_dim,
            w1: Matrix::from_fn(hidden, in_dim, |_, _| rng.uniform_in(-bound1, bound1)),
            b1: vec![0.0; hidden],
            w2: Matrix::from_fn(out_dim, hidden, |_, _| rng.uniform_in(-bound2, bound2)),
            b2: vec![0.0; out_dim],
        }
    }

    pub fn num_params(&self) -> usize {
        self.hidden * self.in_dim + self.hidden + self.out_dim * self.hidden + self.out_dim
    }

    pub fn make_grad(&self) -> ReadoutGrad {
        ReadoutGrad { flat: vec![0.0; self.num_params()] }
    }

    /// Logits for hidden state `h` (allocation-free after the first call).
    // audit: hot-path
    pub fn forward(&self, h: &[f32], cache: &mut ReadoutCache) {
        debug_assert_eq!(h.len(), self.in_dim);
        cache.h_in.resize(self.in_dim, 0.0);
        cache.h_in.copy_from_slice(h);
        cache.pre1.resize(self.hidden, 0.0);
        matvec_into(&self.w1, h, &mut cache.pre1);
        for (p, b) in cache.pre1.iter_mut().zip(&self.b1) {
            *p += b;
        }
        cache.act1.resize(self.hidden, 0.0);
        for (a, &p) in cache.act1.iter_mut().zip(&cache.pre1) {
            *a = p.max(0.0);
        }
        cache.logits.resize(self.out_dim, 0.0);
        matvec_into(&self.w2, &cache.act1, &mut cache.logits);
        for (l, b) in cache.logits.iter_mut().zip(&self.b2) {
            *l += b;
        }
    }

    /// Cross-entropy loss vs `target`; accumulates readout grads into `g`
    /// and returns `(loss_nats, dL/dh)` — the cotangent borrows the cache's
    /// scratch, so the per-token hot loop allocates nothing.
    // audit: hot-path
    pub fn loss_and_backward<'a>(
        &self,
        cache: &'a mut ReadoutCache,
        target: usize,
        g: &mut ReadoutGrad,
    ) -> (f32, &'a [f32]) {
        // softmax gradient in the cache scratch: grad = softmax(logits) − e_t
        cache.dlogits.resize(self.out_dim, 0.0);
        cache.dlogits.copy_from_slice(&cache.logits);
        log_softmax(&mut cache.dlogits);
        let loss = -cache.dlogits[target];
        for v in cache.dlogits.iter_mut() {
            *v = v.exp();
        }
        cache.dlogits[target] -= 1.0;
        let dh = self.backward_scratch(cache, g);
        (loss, dh)
    }

    /// Backprop an arbitrary logit cotangent (copied into the cache's
    /// scratch; the returned `∂L/∂h` borrows the cache).
    // audit: hot-path
    pub fn backward<'a>(
        &self,
        cache: &'a mut ReadoutCache,
        dlogits: &[f32],
        g: &mut ReadoutGrad,
    ) -> &'a [f32] {
        cache.dlogits.resize(self.out_dim, 0.0);
        cache.dlogits.copy_from_slice(dlogits);
        self.backward_scratch(cache, g)
    }

    /// Shared backward sweep reading the cotangent from `cache.dlogits`.
    // audit: hot-path
    fn backward_scratch<'a>(&self, cache: &'a mut ReadoutCache, g: &mut ReadoutGrad) -> &'a [f32] {
        let (o_w1, o_b1, o_w2, o_b2) = self.offsets();
        // dW2 = dlogits ⊗ act1 ; db2 = dlogits
        for (i, &dl) in cache.dlogits.iter().enumerate() {
            if dl != 0.0 {
                axpy_slice(
                    &mut g.flat[o_w2 + i * self.hidden..o_w2 + (i + 1) * self.hidden],
                    dl,
                    &cache.act1,
                );
            }
            g.flat[o_b2 + i] += dl;
        }
        // dact1 = W2ᵀ dlogits, gated by relu'
        cache.dact1.resize(self.hidden, 0.0);
        matvec_t_into(&self.w2, &cache.dlogits, &mut cache.dact1);
        for (da, &pre) in cache.dact1.iter_mut().zip(&cache.pre1) {
            *da *= drelu(pre);
        }
        // dW1 = dact1 ⊗ h ; db1 = dact1
        for (i, &da) in cache.dact1.iter().enumerate() {
            if da != 0.0 {
                axpy_slice(
                    &mut g.flat[o_w1 + i * self.in_dim..o_w1 + (i + 1) * self.in_dim],
                    da,
                    &cache.h_in,
                );
            }
            g.flat[o_b1 + i] += da;
        }
        // dL/dh = W1ᵀ dact1
        cache.dh.resize(self.in_dim, 0.0);
        matvec_t_into(&self.w1, &cache.dact1, &mut cache.dh);
        &cache.dh
    }

    fn offsets(&self) -> (usize, usize, usize, usize) {
        let o_w1 = 0;
        let o_b1 = o_w1 + self.hidden * self.in_dim;
        let o_w2 = o_b1 + self.hidden;
        let o_b2 = o_w2 + self.out_dim * self.hidden;
        (o_w1, o_b1, o_w2, o_b2)
    }

    /// Apply a flat delta: `params += delta` (optimizer writes).
    pub fn apply_delta(&mut self, delta: &[f32]) {
        assert_eq!(delta.len(), self.num_params());
        let (o_w1, o_b1, o_w2, o_b2) = self.offsets();
        let w1 = self.w1.as_mut_slice();
        for (i, v) in w1.iter_mut().enumerate() {
            *v += delta[o_w1 + i];
        }
        for (i, v) in self.b1.iter_mut().enumerate() {
            *v += delta[o_b1 + i];
        }
        let w2 = self.w2.as_mut_slice();
        for (i, v) in w2.iter_mut().enumerate() {
            *v += delta[o_w2 + i];
        }
        for (i, v) in self.b2.iter_mut().enumerate() {
            *v += delta[o_b2 + i];
        }
    }

    /// Flat parameter vector (layout: W1 row-major, b1, W2 row-major, b2 —
    /// the same layout `apply_delta` consumes and the AOT artifacts mirror).
    pub fn params_flat(&self) -> Vec<f32> {
        let mut flat = Vec::with_capacity(self.num_params());
        flat.extend_from_slice(self.w1.as_slice());
        flat.extend_from_slice(&self.b1);
        flat.extend_from_slice(self.w2.as_slice());
        flat.extend_from_slice(&self.b2);
        flat
    }

    /// Overwrite all parameters from a flat vector.
    pub fn set_params(&mut self, flat: &[f32]) {
        assert_eq!(flat.len(), self.num_params());
        let (o_w1, o_b1, o_w2, o_b2) = self.offsets();
        self.w1.as_mut_slice().copy_from_slice(&flat[o_w1..o_b1]);
        self.b1.copy_from_slice(&flat[o_b1..o_w2]);
        self.w2.as_mut_slice().copy_from_slice(&flat[o_w2..o_b2]);
        self.b2.copy_from_slice(&flat[o_b2..]);
    }

    /// FLOPs of one forward pass.
    pub fn forward_flops(&self) -> u64 {
        2 * (self.hidden * self.in_dim + self.out_dim * self.hidden) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::softmax_xent;

    #[test]
    fn forward_backward_finite_diff() {
        let mut rng = Pcg32::seeded(1000);
        let mut ro = Readout::new(5, 7, 4, &mut rng);
        let h: Vec<f32> = (0..5).map(|_| rng.normal()).collect();
        let target = 2usize;
        let mut cache = ReadoutCache::default();
        ro.forward(&h, &mut cache);
        let mut g = ro.make_grad();
        let (_, dh) = ro.loss_and_backward(&mut cache, target, &mut g);
        let dh = dh.to_vec();

        // FD over h.
        let eps = 1e-3f32;
        for l in 0..5 {
            let mut hp = h.clone();
            hp[l] += eps;
            let mut c1 = ReadoutCache::default();
            ro.forward(&hp, &mut c1);
            let (l1, _) = softmax_xent(&c1.logits, target);
            hp[l] -= 2.0 * eps;
            let mut c2 = ReadoutCache::default();
            ro.forward(&hp, &mut c2);
            let (l2, _) = softmax_xent(&c2.logits, target);
            let fd = (l1 - l2) / (2.0 * eps);
            assert!((fd - dh[l]).abs() < 2e-3, "dh[{l}]: fd={fd} an={}", dh[l]);
        }

        // FD over params via apply_delta on a few coordinates.
        let n = ro.num_params();
        for j in (0..n).step_by((n / 20).max(1)) {
            let mut delta = vec![0.0f32; n];
            delta[j] = eps;
            ro.apply_delta(&delta);
            let mut c1 = ReadoutCache::default();
            ro.forward(&h, &mut c1);
            let (l1, _) = softmax_xent(&c1.logits, target);
            delta[j] = -2.0 * eps;
            ro.apply_delta(&delta);
            let mut c2 = ReadoutCache::default();
            ro.forward(&h, &mut c2);
            let (l2, _) = softmax_xent(&c2.logits, target);
            delta[j] = eps;
            ro.apply_delta(&delta); // restore
            let fd = (l1 - l2) / (2.0 * eps);
            assert!((fd - g.flat[j]).abs() < 2e-3, "param {j}: fd={fd} an={}", g.flat[j]);
        }
    }

    #[test]
    fn grad_accumulate_and_clear() {
        let mut rng = Pcg32::seeded(1003);
        let ro = Readout::new(3, 4, 2, &mut rng);
        let mut a = ro.make_grad();
        let mut b = ro.make_grad();
        a.flat.iter_mut().enumerate().for_each(|(i, v)| *v = i as f32);
        b.flat.iter_mut().for_each(|v| *v = 0.5);
        a.accumulate_from(&b);
        assert_eq!(a.flat[0], 0.5);
        assert_eq!(a.flat[2], 2.5);
        b.clear();
        assert!(b.flat.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn param_count() {
        let mut rng = Pcg32::seeded(1001);
        let ro = Readout::new(128, 1024, 256, &mut rng);
        assert_eq!(ro.num_params(), 1024 * 128 + 1024 + 256 * 1024 + 256);
    }

    #[test]
    fn loss_decreases_under_gradient_steps() {
        let mut rng = Pcg32::seeded(1002);
        let mut ro = Readout::new(4, 8, 3, &mut rng);
        let h = vec![0.5f32, -0.3, 0.8, 0.1];
        let target = 1;
        let mut cache = ReadoutCache::default();
        ro.forward(&h, &mut cache);
        let (l0, _) = softmax_xent(&cache.logits, target);
        for _ in 0..50 {
            let mut g = ro.make_grad();
            ro.forward(&h, &mut cache);
            ro.loss_and_backward(&mut cache, target, &mut g);
            let delta: Vec<f32> = g.flat.iter().map(|&x| -0.1 * x).collect();
            ro.apply_delta(&delta);
        }
        ro.forward(&h, &mut cache);
        let (l1, _) = softmax_xent(&cache.logits, target);
        assert!(l1 < l0 * 0.5, "l0={l0} l1={l1}");
    }
}
