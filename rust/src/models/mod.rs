//! Task heads: fixed random token embedding + the readout MLP.
//!
//! The recurrent core consumes a dense input vector. For byte-level language
//! modelling we embed tokens with a **frozen random embedding** (the paper
//! does not specify its input encoding; a frozen projection keeps every
//! trained parameter inside either the recurrent core — handled by the RTRL
//! family — or the readout — handled by exact backprop, so the comparison
//! between gradient algorithms stays clean). One-hot encoding is available
//! for the small-alphabet Copy task.

pub mod readout;

pub use readout::{Readout, ReadoutCache, ReadoutGrad};

use crate::tensor::matrix::Matrix;
use crate::tensor::rng::Pcg32;

/// Frozen random embedding table (vocab × dim).
pub struct Embedding {
    table: Matrix,
}

impl Embedding {
    pub fn new(vocab: usize, dim: usize, rng: &mut Pcg32) -> Self {
        let std = (1.0 / (dim as f64).sqrt()) as f32;
        Embedding { table: Matrix::from_fn(vocab, dim, |_, _| rng.normal() * std) }
    }

    /// One-hot "embedding" (identity table).
    pub fn one_hot(vocab: usize) -> Self {
        Embedding { table: Matrix::identity(vocab) }
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.table.cols()
    }

    #[inline]
    pub fn vocab(&self) -> usize {
        self.table.rows()
    }

    #[inline]
    pub fn lookup(&self, token: usize) -> &[f32] {
        self.table.row(token)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_hot_lookup() {
        let e = Embedding::one_hot(4);
        assert_eq!(e.lookup(2), &[0.0, 0.0, 1.0, 0.0]);
        assert_eq!(e.dim(), 4);
    }

    #[test]
    fn random_embedding_deterministic() {
        let mut r1 = Pcg32::seeded(1);
        let mut r2 = Pcg32::seeded(1);
        let a = Embedding::new(10, 8, &mut r1);
        let b = Embedding::new(10, 8, &mut r2);
        assert_eq!(a.lookup(3), b.lookup(3));
    }
}
