//! L3 coordinator: CLI, experiment registry (one command per paper
//! table/figure), reporting, the approximation-quality analysis, and the
//! CI bench-regression gate.

pub mod analysis;
pub mod benchgate;
pub mod cli;
pub mod experiments;
pub mod report;

pub use cli::{Args, USAGE};

use crate::errors::Result;

/// Dispatch a parsed command. Returns Err for unknown commands.
pub fn dispatch(args: &Args) -> Result<()> {
    match args.command.as_str() {
        "table1" => experiments::run_table1(args),
        "fig3" => experiments::run_fig3(args)?,
        "table2" | "fig4" => experiments::run_table2(args),
        "table3" => experiments::run_table3(args),
        "table4" | "fig6" => experiments::run_table4(args),
        "fig5" => experiments::run_fig5(args),
        "train" => experiments::run_train(args)?,
        "copy" => experiments::run_copy_cmd(args)?,
        "file-lm" => experiments::run_file_lm(args)?,
        "bench-gate" => benchgate::run_bench_gate(args)?,
        "audit" => crate::analysis::run_audit_cli(args)?,
        "serve" => crate::serve::run_serve_cli(args)?,
        "shard-coordinator" => crate::shard::run_shard_coordinator(args)?,
        "shard-worker" => crate::shard::run_shard_worker(args)?,
        "aot-demo" => crate::runtime::demo::run_aot_demo(args)?,
        "info" => info(),
        "help" | "--help" | "-h" => println!("{USAGE}"),
        other => crate::bail!("unknown command '{other}'\n\n{USAGE}"),
    }
    Ok(())
}

fn info() {
    println!("snap-rtrl {} — SnAp reproduction", env!("CARGO_PKG_VERSION"));
    println!("artifacts dir: {}", crate::runtime::artifacts_dir().display());
    println!("results dir:   {}", crate::coordinator::report::results_dir().display());
    match crate::runtime::PjrtRuntime::cpu() {
        Ok(rt) => println!("PJRT: platform={} devices={}", rt.platform(), rt.device_count()),
        Err(e) => println!("PJRT: unavailable ({e})"),
    }
}
