//! Experiment registry — one entry per table/figure of the paper's
//! evaluation section (see DESIGN.md's experiment index). Every command
//! prints the paper-shaped table on stdout and writes CSVs under results/.

use crate::benchutil::{write_bench_json, JsonObj};
use crate::cells::Arch;
use crate::coordinator::analysis::{run_table4 as analysis_table4, Table4Config};
use crate::coordinator::cli::Args;
use crate::coordinator::report::{f2, f3, floats_h, mult, pct, results_dir, write_csv, Table};
use crate::data::{Corpus, Dataset, DatasetOptions, DatasetSpec};
use crate::errors::{Context as _, Result};
use crate::grad::Method;
use crate::sparse::pattern::{snap_pattern, Pattern};
use crate::train::{
    table1_memory, table1_time, train_charlm, train_charlm_streams, train_copy,
    try_train_charlm_streams, try_train_copy, CostInputs, TrainConfig, TrainResult,
};
use crate::tensor::rng::Pcg32;
use std::path::PathBuf;

// ---------------------------------------------------------------------------
// Dataset resolution (the --dataset registry; see data::stream)
// ---------------------------------------------------------------------------

fn dataset_options(args: &Args) -> DatasetOptions {
    DatasetOptions {
        valid_frac: args.f64_or("valid-frac", 0.05),
        lowercase: args.bool_or("lowercase", false),
        ..Default::default()
    }
}

/// Resolve `--dataset` (falling back to the legacy `--corpus PATH` alias,
/// then to the synthetic default) into train/valid sources. `pub(crate)`
/// for the shard coordinator, which loads data with exactly the `train`
/// command's wiring.
pub(crate) fn dataset_from_args(args: &Args) -> Result<Dataset> {
    let synthetic_default = || DatasetSpec::Synthetic {
        bytes: args.usize_or("corpus-bytes", 200_000),
        seed: args.u64_or("corpus-seed", 1234),
    };
    let spec = match args.get("dataset") {
        // Bare "synthetic" keeps honoring --corpus-bytes/--corpus-seed;
        // an explicit synthetic:BYTES[:SEED] spec pins them instead.
        Some("synthetic") => synthetic_default(),
        Some(s) => DatasetSpec::parse(s)?,
        None => match args.get("corpus") {
            Some(path) => DatasetSpec::File(path.into()),
            None => synthetic_default(),
        },
    };
    spec.load(&dataset_options(args))
}

// ---------------------------------------------------------------------------
// Table 1 — asymptotic cost model + measured counters
// ---------------------------------------------------------------------------

pub fn run_table1(args: &Args) {
    let k = args.usize_or("k", 128);
    let t = args.usize_or("t", 128);
    let sparsity = args.f64_or("sparsity", 0.75);
    let d = 1.0 - sparsity;
    let arch = Arch::parse(&args.str_or("arch", "gru")).expect("bad --arch");
    let input = args.usize_or("input-dim", 64);
    let p = crate::train::flops::dense_params(arch, k, input);

    println!(
        "# Table 1 — costs of gradient methods (k={k}, T={t}, p={p}, sparsity={sparsity})\n"
    );
    println!("Asymptotic entries evaluate the paper's formulas; measured columns come");
    println!("from the instrumented algorithms on a {} cell at the same shape.\n", arch.name());

    let methods: Vec<(Method, f64)> = vec![
        (Method::Bptt, 1.0),
        (Method::Uoro, 1.0),
        (Method::Rtrl, 1.0),
        (Method::Bptt, d),
        (Method::Rtrl, d), // printed as Sparse RTRL via SparseRtrl below
        (Method::Snap(1), d),
        (Method::Snap(2), d),
    ];

    let mut tbl = Table::new(&[
        "method",
        "memory (asymptotic)",
        "time/step (asymptotic)",
        "measured mem (floats)",
        "measured flops/step",
    ]);
    let mut csv_rows = Vec::new();

    for (m, dd) in methods {
        let c = CostInputs { t, k, p, d: dd };
        let label = match (m, dd < 1.0) {
            (Method::Bptt, true) => "Sparse BPTT".to_string(),
            (Method::Rtrl, true) => "Sparse RTRL".to_string(),
            (mm, _) => mm.name().to_uppercase(),
        };
        let mm = if let (Method::Rtrl, true) = (m, dd < 1.0) { Method::SparseRtrl } else { m };
        let mem = table1_memory(mm, c);
        let time = table1_time(mm, c);

        // Measured: run a few steps on a scaled-down cell (same d).
        let (meas_mem, meas_flops) = measure_cost(arch, 32.min(k), 16.min(input), dd, mm);
        tbl.row(&[
            label.clone(),
            floats_h(mem),
            floats_h(time),
            floats_h(meas_mem as f64),
            floats_h(meas_flops),
        ]);
        csv_rows.push(vec![
            label,
            format!("{mem}"),
            format!("{time}"),
            format!("{meas_mem}"),
            format!("{meas_flops}"),
        ]);
    }
    tbl.print();
    let p = write_csv(
        "table1.csv",
        &["method", "mem_asym", "time_asym", "mem_meas", "flops_meas"],
        &csv_rows,
    );
    println!("\nwrote {}", p.display());
}

fn measure_cost(arch: Arch, k: usize, input: usize, d: f64, m: Method) -> (usize, f64) {
    let mut rng = Pcg32::seeded(42);
    let cell = arch.build(k, input, d, &mut rng);
    let theta = cell.init_params(&mut rng);
    let mut algo = m.build(cell.as_ref(), &mut rng);
    let mut g = vec![0.0f32; cell.num_params()];
    let dl: Vec<f32> = (0..cell.hidden_size()).map(|_| 0.1).collect();
    let mut fl = 0u64;
    let steps = 8;
    for _ in 0..steps {
        let x: Vec<f32> = (0..input).map(|_| rng.normal()).collect();
        algo.step(&theta, &x);
        algo.inject_loss(&dl, &mut g);
        fl += algo.tracking_flops_per_step();
    }
    algo.flush(&theta, &mut g);
    (algo.tracking_memory_floats(), fl as f64 / steps as f64)
}

// ---------------------------------------------------------------------------
// Figure 3 — char-LM learning curves (dense & 75% sparse)
// ---------------------------------------------------------------------------

pub fn run_fig3(args: &Args) -> Result<()> {
    let side = args.str_or("side", "both");
    let steps = args.usize_or("steps", 300);
    let k = args.usize_or("k", 64);
    let batch = args.usize_or("batch", 1);
    let lr = args.f32_or("lr", 3e-3);
    let seed = args.u64_or("seed", 1);
    let ds = dataset_from_args(args)?;

    let workers = args.usize_or("workers", 1);
    if side == "dense" || side == "both" {
        fig3_side(&ds, false, steps, k, batch, lr, seed, workers);
    }
    if side == "sparse" || side == "both" {
        fig3_side(&ds, true, steps, k, batch, lr, seed, workers);
    }
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn fig3_side(
    ds: &Dataset,
    sparse: bool,
    steps: usize,
    k: usize,
    batch: usize,
    lr: f32,
    seed: u64,
    workers: usize,
) {
    let density = if sparse { 0.25 } else { 1.0 };
    let label = if sparse { "sparse75" } else { "dense" };
    let mut methods: Vec<Method> =
        vec![Method::Bptt, Method::Snap(1), Method::Uoro, Method::Rflo, Method::Frozen];
    if sparse {
        methods.insert(2, Method::Snap(2));
    }

    println!(
        "# Figure 3 ({label}) — GRU-{k} char-LM, methods: {:?}",
        methods.iter().map(|m| m.name()).collect::<Vec<_>>()
    );

    let results: Vec<(Method, TrainResult)> = parallel_map(&methods, |&m| {
        let cfg = TrainConfig {
            arch: Arch::Gru,
            k,
            density,
            method: m,
            lr,
            batch,
            seq_len: 128,
            truncation: 0, // §5.1.1: update at end of sequence; BPTT is gold
            steps,
            seed,
            readout_hidden: 256,
            embed_dim: 64,
            log_every: (steps / 30).max(1),
            workers,
            ..Default::default()
        };
        (m, train_charlm_streams(&cfg, ds.train.as_ref(), ds.valid.as_ref()))
    });

    let mut tbl = Table::new(&["method", "final train bpc", "final valid bpc"]);
    let mut csv = Vec::new();
    for (m, res) in &results {
        tbl.row(&[m.name(), f3(res.final_train_bpc), f3(res.final_valid_bpc)]);
        for pt in &res.curve {
            csv.push(vec![
                m.name(),
                pt.x.to_string(),
                format!("{:.5}", pt.train_bpc),
                format!("{:.5}", pt.valid_bpc),
            ]);
        }
    }
    tbl.print();
    let p = write_csv(
        &format!("fig3_{label}.csv"),
        &["method", "step", "train_bpc", "valid_bpc"],
        &csv,
    );
    println!("wrote {}\n", p.display());
}

// ---------------------------------------------------------------------------
// Table 2 / Figure 4 — bpc vs sparsity at constant parameter count
// ---------------------------------------------------------------------------

pub fn run_table2(args: &Args) {
    let steps = args.usize_or("steps", 250);
    let base_k = args.usize_or("base-k", 32);
    let max_mult = args.usize_or("max-mult", 8);
    let lr = args.f32_or("lr", 3e-3);
    let corpus = Corpus::synthetic(args.usize_or("corpus-bytes", 200_000), 1234);
    let seed = args.u64_or("seed", 1);

    // Rows: (units multiplier, target sparsity). Constant parameter count:
    // k·mult with sparsity 1 - 1/mult² keeps k² weights fixed.
    let mut rows: Vec<(usize, f64, &str)> = vec![(1, 0.0, "base")];
    let mut m = 2usize;
    while m <= max_mult {
        rows.push((m, 1.0 - 1.0 / (m * m) as f64, "sparse"));
        m *= 2;
    }
    // the paper's 2.5x-dense comparison row (6.25x params)
    rows.push((5, 0.0, "dense2.5x")); // 5/2 = 2.5x units of base → run at k*5/2

    println!("# Table 2 / Figure 4 — BPC vs sparsity at constant parameter count");
    println!("(base k={base_k}, pruning to target via Zhu-Gupta every --prune-every steps)\n");

    let results: Vec<((usize, f64, String), TrainResult)> =
        parallel_map(&rows, |&(mult_i, sparsity, tag)| {
            let k = if tag == "dense2.5x" { base_k * 5 / 2 } else { base_k * mult_i };
            let cfg = TrainConfig {
                arch: Arch::Gru,
                k,
                density: 1.0, // pruning runs start dense and prune progressively
                method: Method::Bptt,
                lr,
                batch: 1,
                seq_len: 64,
                truncation: 0,
                steps,
                seed,
                readout_hidden: 128,
                embed_dim: 32,
                log_every: (steps / 10).max(1),
                prune_to: if sparsity > 0.0 { Some(sparsity) } else { None },
                prune_every: args.u64_or("prune-every", 20),
                prune_end_step: (steps as u64) * 7 / 10,
                ..Default::default()
            };
            ((mult_i, sparsity, tag.to_string()), train_charlm(&cfg, &corpus))
        });

    let mut tbl = Table::new(&["units", "bpc", "θ sparsity", "|θ| (×base)"]);
    let mut csv = Vec::new();
    for ((mult_i, sparsity, tag), res) in &results {
        let units = if tag == "dense2.5x" {
            format!("{:.1}x (dense)", 2.5)
        } else if *mult_i == 1 {
            "base".to_string()
        } else {
            format!("{mult_i}x")
        };
        let rel_params = if tag == "dense2.5x" { 6.25 } else { 1.0 };
        tbl.row(&[
            units.clone(),
            f2(res.final_valid_bpc),
            pct(*sparsity),
            format!("{rel_params}x"),
        ]);
        csv.push(vec![
            units,
            format!("{:.5}", res.final_valid_bpc),
            format!("{sparsity}"),
            format!("{rel_params}"),
        ]);
    }
    tbl.print();
    let p = write_csv("table2_fig4.csv", &["units", "bpc", "sparsity", "rel_params"], &csv);
    println!("\nwrote {}", p.display());
}

// ---------------------------------------------------------------------------
// Table 3 — empirical FLOPs / Jacobian sparsity (exact, deterministic)
// ---------------------------------------------------------------------------

pub fn run_table3(args: &Args) {
    let input = args.usize_or("input-dim", 64);
    let seed = args.u64_or("seed", 42);
    let shared = args.bool_or("shared-mask", false);
    let configs: Vec<(usize, f64)> = vec![(128, 0.75), (256, 0.9375), (512, 0.984)];
    let archs = [Arch::Vanilla, Arch::Gru, Arch::Lstm];

    println!("# Table 3 — empirical costs of SnAp (input-dim={input}, shared-mask={shared})\n");
    let mut tbl = Table::new(&[
        "arch", "units", "param sparsity", "SnAp-2 J sparsity", "SnAp-3 J sparsity",
        "SnAp-1 vs BPTT", "SnAp-2 vs BPTT", "SnAp-3 vs BPTT", "SnAp-2 vs SparseRTRL",
    ]);
    let mut csv = Vec::new();

    for arch in archs {
        for &(k, sparsity) in &configs {
            let row = table3_row_opts(arch, k, input, 1.0 - sparsity, seed, shared);
            tbl.row(&[
                arch.name().to_string(),
                k.to_string(),
                pct(sparsity),
                pct(row.j2_sparsity),
                pct(row.j3_sparsity),
                mult(row.snap1_vs_bptt),
                mult(row.snap2_vs_bptt),
                mult(row.snap3_vs_bptt),
                format!("{:.3}x", row.snap2_vs_rtrl),
            ]);
            csv.push(vec![
                arch.name().into(), k.to_string(), format!("{sparsity}"),
                format!("{:.4}", row.j2_sparsity), format!("{:.4}", row.j3_sparsity),
                format!("{:.2}", row.snap1_vs_bptt), format!("{:.2}", row.snap2_vs_bptt),
                format!("{:.2}", row.snap3_vs_bptt), format!("{:.4}", row.snap2_vs_rtrl),
            ]);
        }
    }
    tbl.print();
    let p = write_csv(
        "table3.csv",
        &[
            "arch",
            "units",
            "sparsity",
            "j2_sparsity",
            "j3_sparsity",
            "snap1_vs_bptt",
            "snap2_vs_bptt",
            "snap3_vs_bptt",
            "snap2_vs_rtrl",
        ],
        &csv,
    );
    println!("\nwrote {}", p.display());
}

pub struct Table3Row {
    pub j2_sparsity: f64,
    pub j3_sparsity: f64,
    pub snap1_vs_bptt: f64,
    pub snap2_vs_bptt: f64,
    pub snap3_vs_bptt: f64,
    pub snap2_vs_rtrl: f64,
}

/// Exact pattern/FLOP computation for one Table 3 cell.
pub fn table3_row(arch: Arch, k: usize, input: usize, density: f64, seed: u64) -> Table3Row {
    table3_row_opts(arch, k, input, density, seed, false)
}

/// As `table3_row`, optionally with ONE random mask shared across all gate
/// matrices (instead of independent per-gate masks). Sharing keeps `pat(D)`
/// as sparse as a single mask, which reproduces the paper's higher SnAp-2
/// J-sparsity numbers for gated cells — evidence the paper shared patterns
/// across gates (it only says "a sparsity pattern", singular, in §5.1.2).
pub fn table3_row_opts(
    arch: Arch,
    k: usize,
    input: usize,
    density: f64,
    seed: u64,
    shared_mask: bool,
) -> Table3Row {
    use crate::cells::{Cell, Gru, Lstm, Vanilla};
    let mut rng = Pcg32::seeded(seed);
    let cell: Box<dyn Cell> = if !shared_mask {
        arch.build(k, input, density, &mut rng)
    } else {
        let mh = Pattern::random(k, k, density, &mut rng);
        let mx = Pattern::random(k, input, density, &mut rng);
        match arch {
            Arch::Vanilla => Box::new(Vanilla::new(k, input, density, &mut rng)),
            Arch::Gru => Box::new(Gru::with_masks(
                k, input, density,
                [mh.clone(), mh.clone(), mh.clone()],
                [mx.clone(), mx.clone(), mx.clone()],
            )),
            Arch::Lstm => Box::new(Lstm::with_masks(
                k, input, density,
                [mh.clone(), mh.clone(), mh.clone(), mh.clone()],
                [mx.clone(), mx.clone(), mx.clone(), mx.clone()],
            )),
        }
    };
    let d_pat = cell.dynamics_pattern();
    let i_pat = cell.immediate_structure().pattern();
    let p1 = i_pat.clone();
    let p2 = snap_pattern(&d_pat, &i_pat, 2);
    let p3 = snap_pattern(&d_pat, &i_pat, 3);

    let p = cell.num_params();

    // per-step FLOPs
    let snap_flops = |pat: &crate::sparse::pattern::Pattern| -> f64 {
        let (col_ptr, _) = pat.to_csc();
        let update: u64 = (0..pat.cols())
            .map(|j| {
                let n = (col_ptr[j + 1] - col_ptr[j]) as u64;
                2 * n * n
            })
            .sum();
        (update + 2 * pat.nnz() as u64) as f64 + cell.forward_flops() as f64
    };
    // Sparse-D contract: BPTT's backward step is a sparse Dᵀδ — 2·nnz(D),
    // the paper's Sparse-BPTT `d·k²` term — not the dense 2·(state)².
    let bptt = (2 * d_pat.nnz() + 2 * i_pat.nnz()) as f64 + cell.forward_flops() as f64;
    let sparse_rtrl = (2 * d_pat.nnz() * p) as f64 + cell.forward_flops() as f64;

    Table3Row {
        j2_sparsity: p2.sparsity(),
        j3_sparsity: p3.sparsity(),
        snap1_vs_bptt: snap_flops(&p1) / bptt,
        snap2_vs_bptt: snap_flops(&p2) / bptt,
        snap3_vs_bptt: snap_flops(&p3) / bptt,
        snap2_vs_rtrl: snap_flops(&p2) / sparse_rtrl,
    }
}

// ---------------------------------------------------------------------------
// Table 4 / Figure 6 — approximation quality
// ---------------------------------------------------------------------------

pub fn run_table4(args: &Args) {
    let checkpoints: Vec<u64> = args
        .list_or("checkpoints", &["100", "500", "1000", "2500", "5000"])
        .iter()
        .map(|s| s.parse().expect("bad checkpoint"))
        .collect();
    let cfg = Table4Config {
        k: args.usize_or("k", 8),
        density: 1.0 - args.f64_or("sparsity", 0.75),
        target_len: args.usize_or("target-len", 16),
        lr: args.f32_or("lr", 1e-3),
        seed: args.u64_or("seed", 7),
        checkpoints,
    };
    println!(
        "# Table 4 / Figure 6 — SnAp approximation quality ({}-unit GRU, {:.0}% sparse, len {})\n",
        cfg.k,
        (1.0 - cfg.density) * 100.0,
        cfg.target_len
    );
    let (stats, dump) = analysis_table4(&cfg);
    let mut tbl = Table::new(&[
        "training step",
        "SnAp-1 mean|J| (mass%)",
        "SnAp-2 mean|J| (mass%)",
        "ignored mean|J|",
    ]);
    let mut csv = Vec::new();
    for s in &stats {
        tbl.row(&[
            s.step.to_string(),
            format!("{:.1e} ({:.0}%)", s.mean_kept_snap1, s.mass_frac_snap1 * 100.0),
            format!("{:.1e} ({:.0}%)", s.mean_kept_snap2, s.mass_frac_snap2 * 100.0),
            format!("{:.1e}", s.mean_ignored),
        ]);
        csv.push(vec![
            s.step.to_string(),
            format!("{}", s.mean_kept_snap1),
            format!("{}", s.mass_frac_snap1),
            format!("{}", s.mean_kept_snap2),
            format!("{}", s.mass_frac_snap2),
            format!("{}", s.mean_ignored),
        ]);
    }
    tbl.print();
    let p = write_csv(
        "table4.csv",
        &["step", "snap1_mean", "snap1_mass", "snap2_mean", "snap2_mass", "ignored_mean"],
        &csv,
    );
    let fig6: Vec<Vec<String>> = dump
        .iter()
        .map(|(i, j, v, cat)| vec![i.to_string(), j.to_string(), format!("{v}"), cat.to_string()])
        .collect();
    let p6 = write_csv("fig6_influence.csv", &["row", "col", "abs_value", "category"], &fig6);
    println!("\nwrote {} and {}", p.display(), p6.display());
}

// ---------------------------------------------------------------------------
// Figure 5 — Copy-task curriculum curves
// ---------------------------------------------------------------------------

pub fn run_fig5(args: &Args) {
    let archs: Vec<Arch> = args
        .list_or("arch", &["vanilla", "gru", "lstm"])
        .iter()
        .map(|s| Arch::parse(s).expect("bad arch"))
        .collect();
    let sparsity = args.f64_or("sparsity", 0.75);
    let k = args.usize_or("k", 32);
    let steps = args.usize_or("steps", 150);
    let batch = args.usize_or("batch", 4);
    let seeds: Vec<u64> = (0..args.u64_or("seeds", 2)).collect();
    let lrs: Vec<f32> = args
        .list_or("lrs", &["0.003"])
        .iter()
        .map(|s| s.parse().expect("bad lr"))
        .collect();
    let method_names = args.list_or(
        "methods",
        &["bptt-online", "bptt-full", "snap-1", "snap-2", "snap-3", "rflo"],
    );
    let workers = args.usize_or("workers", 1);
    if workers != 1 {
        println!(
            "WARNING: --workers {workers} changes the *algorithm* for online (truncated) Copy \
arms, not just throughput: they run the batched-online schedule instead of the paper's \
per-token updates (see train::looper docs). Use --workers 1 for paper-faithful curves.\n"
        );
    }

    println!(
        "# Figure 5 — Copy task (k={k}, sparsity={sparsity}, {steps} minibatches of {batch})\n"
    );

    // (arch, method-name, online?) arms
    let mut arms: Vec<(Arch, String, Method, usize)> = Vec::new();
    for &arch in &archs {
        for name in &method_names {
            let (m, trunc) = match name.as_str() {
                "bptt-online" => (Method::Bptt, 1),
                "bptt-full" => (Method::Bptt, 0),
                other => (
                    Method::parse(other).unwrap_or_else(|| panic!("bad method {other}")),
                    1, // RTRL approximations run fully online (§5.2)
                ),
            };
            arms.push((arch, name.clone(), m, trunc));
        }
    }

    let results: Vec<((Arch, String), Vec<(u64, f64)>, usize)> =
        parallel_map(&arms, |(arch, name, m, trunc)| {
            // lr sweep × seeds; keep the best lr by final level, average seeds.
            let mut best: Option<(usize, Vec<(u64, f64)>)> = None;
            for &lr in &lrs {
                let mut curves: Vec<Vec<(u64, f64)>> = Vec::new();
                let mut final_levels = 0usize;
                for &seed in &seeds {
                    let cfg = TrainConfig {
                        arch: *arch,
                        k,
                        density: 1.0 - sparsity,
                        method: *m,
                        lr,
                        batch,
                        truncation: *trunc,
                        steps,
                        seed: seed + 100,
                        readout_hidden: 64,
                        log_every: 1,
                        workers,
                        ..Default::default()
                    };
                    let res = train_copy(&cfg);
                    final_levels += res.final_level;
                    curves.push(res.curve.iter().map(|p| (p.x, p.aux)).collect());
                }
                let avg = average_curves(&curves);
                if best.as_ref().map(|(l, _)| final_levels > *l).unwrap_or(true) {
                    best = Some((final_levels, avg));
                }
            }
            let (levels, curve) = best.unwrap();
            ((*arch, name.clone()), curve, levels / seeds.len().max(1))
        });

    let mut tbl = Table::new(&["arch", "method", "final curriculum level (avg)"]);
    let mut csv = Vec::new();
    for ((arch, name), curve, level) in &results {
        tbl.row(&[arch.name().to_string(), name.clone(), level.to_string()]);
        for (x, lvl) in curve {
            csv.push(vec![arch.name().into(), name.clone(), x.to_string(), format!("{lvl}")]);
        }
    }
    tbl.print();
    let p = write_csv("fig5_copy.csv", &["arch", "method", "tokens", "level"], &csv);
    println!("\nwrote {}", p.display());
}

fn average_curves(curves: &[Vec<(u64, f64)>]) -> Vec<(u64, f64)> {
    let n = curves.iter().map(|c| c.len()).min().unwrap_or(0);
    (0..n)
        .map(|i| {
            let x = curves[0][i].0;
            let y = curves.iter().map(|c| c[i].1).sum::<f64>() / curves.len() as f64;
            (x, y)
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Single-run commands
// ---------------------------------------------------------------------------

pub fn run_train(args: &Args) -> Result<()> {
    let cfg = config_from_args(args);
    let ds = dataset_from_args(args)?;
    println!("# char-LM: {} {} k={} d={} trunc={} steps={} dataset={}",
        cfg.method.name(), cfg.arch.name(), cfg.k, cfg.density, cfg.truncation, cfg.steps,
        ds.name);
    print_checkpointing(&cfg);
    let res = try_train_charlm_streams(&cfg, ds.train.as_ref(), ds.valid.as_ref())?;
    print_run(&res);
    maybe_dump_state(args, &res)?;
    Ok(())
}

/// One-line echo of the checkpoint/resume knobs so run logs show where the
/// snapshots go (and what a resumed run restarted from).
fn print_checkpointing(cfg: &TrainConfig) {
    if let Some(resume) = &cfg.resume_from {
        println!("# resuming from {}", resume.display());
    }
    if let Some(dir) = cfg.checkpoint_dir.as_ref().filter(|_| cfg.checkpoint_every > 0) {
        println!(
            "# checkpointing every {} steps into {} (keep {})",
            cfg.checkpoint_every,
            dir.display(),
            cfg.checkpoint_keep
        );
    }
}

/// File-corpus preset (the CI `dataset-smoke` job): one end-to-end char-LM
/// run over a file-backed `--dataset`, emitting machine-readable metrics to
/// `results/file_lm_metrics.json` and the learning curve to
/// `results/file_lm_curve.csv`.
pub fn run_file_lm(args: &Args) -> Result<()> {
    let spec_str = args
        .get("dataset")
        .context("file-lm needs --dataset file:PATH or wikitext-dir:DIR")?;
    let spec = DatasetSpec::parse(spec_str)?;
    crate::ensure!(
        !matches!(spec, DatasetSpec::Synthetic { .. }),
        "file-lm is the file-corpus preset; use 'train' for synthetic data"
    );
    let ds = spec.load(&dataset_options(args))?;
    // Same flag wiring as `train`, at smoke-sized defaults.
    let cfg = config_from_args_with(args, &TrainConfig {
        k: 32,
        lr: 3e-3,
        batch: 4,
        seq_len: 64,
        steps: 40,
        readout_hidden: 64,
        embed_dim: 16,
        ..Default::default()
    });
    println!(
        "# file-lm: {} {} k={} over {} (train {} bytes, valid {} bytes)",
        cfg.method.name(),
        cfg.arch.name(),
        cfg.k,
        ds.name,
        ds.train.len_bytes(),
        ds.valid.len_bytes()
    );
    print_checkpointing(&cfg);
    let t0 = std::time::Instant::now();
    let res = try_train_charlm_streams(&cfg, ds.train.as_ref(), ds.valid.as_ref())?;
    let wall = t0.elapsed().as_secs_f64();
    print_run(&res);

    let meta = JsonObj::new()
        .str("dataset", &ds.name)
        .int("train_bytes", ds.train.len_bytes())
        .int("valid_bytes", ds.valid.len_bytes())
        .str("method", &cfg.method.name())
        .str("arch", cfg.arch.name())
        .int("k", cfg.k as u64)
        .int("batch", cfg.batch as u64)
        .int("seq_len", cfg.seq_len as u64)
        .int("steps", cfg.steps as u64)
        .int("workers", cfg.workers as u64);
    let row = JsonObj::new()
        .num("final_train_bpc", res.final_train_bpc)
        .num("final_valid_bpc", res.final_valid_bpc)
        .int("tokens_seen", res.tokens_seen)
        .num("wall_s", wall)
        .num("tokens_per_sec", res.tokens_seen as f64 / wall);
    let metrics_path = results_dir().join("file_lm_metrics.json");
    write_bench_json(&metrics_path.to_string_lossy(), "file_lm", &meta, &[row])?;
    let curve: Vec<Vec<String>> = res
        .curve
        .iter()
        .map(|p| {
            vec![p.x.to_string(), format!("{:.5}", p.train_bpc), format!("{:.5}", p.valid_bpc)]
        })
        .collect();
    let csv_path = write_csv("file_lm_curve.csv", &["step", "train_bpc", "valid_bpc"], &curve);
    println!("wrote {} and {}", metrics_path.display(), csv_path.display());
    Ok(())
}

pub fn run_copy_cmd(args: &Args) -> Result<()> {
    let cfg = config_from_args(args);
    println!("# copy: {} {} k={} d={} trunc={} steps={}",
        cfg.method.name(), cfg.arch.name(), cfg.k, cfg.density, cfg.truncation, cfg.steps);
    if cfg.workers != 1 && cfg.truncation > 0 {
        println!(
            "WARNING: --workers {} with --trunc {} runs the batched-online update schedule, \
not the sequential per-token schedule (see train::looper docs).",
            cfg.workers, cfg.truncation
        );
    }
    print_checkpointing(&cfg);
    let res = try_train_copy(&cfg)?;
    print_run(&res);
    println!("final curriculum level: {}", res.final_level);
    maybe_dump_state(args, &res)?;
    Ok(())
}

/// Honour `--dump-state PATH` on the single-run commands (`train`, `copy`,
/// `shard-coordinator`): write a canonical binary digest of the run's final
/// state so two runs can be compared **byte for byte** (`cmp` in CI, file
/// equality in the determinism tests) instead of parsing stdout.
fn maybe_dump_state(args: &Args, res: &TrainResult) -> Result<()> {
    if let Some(path) = args.get("dump-state") {
        write_state_dump(std::path::Path::new(path), res)?;
        println!("wrote state dump to {path}");
    }
    Ok(())
}

/// Serialize the bitwise-comparable facts of a finished run — θ and readout
/// parameter bits, the full loss curve, token count and final curriculum
/// level — into the standard checksummed container at `path`.
pub(crate) fn write_state_dump(path: &std::path::Path, res: &TrainResult) -> Result<()> {
    use crate::runtime::serde::{encode_container, Writer};
    let mut w = Writer::new();
    w.put_f32s(&res.final_theta);
    w.put_f32s(&res.final_readout);
    w.put_u64(res.curve.len() as u64);
    for p in &res.curve {
        w.put_u64(p.x);
        w.put_f64(p.train_bpc);
        w.put_f64(p.valid_bpc);
        w.put_f64(p.aux);
    }
    w.put_u64(res.tokens_seen);
    w.put_u64(res.final_level as u64);
    let bytes = encode_container(1, &w.into_bytes());
    std::fs::write(path, &bytes)
        .with_context(|| format!("writing state dump '{}'", path.display()))
}

/// `pub(crate)`: the shard coordinator *and* its spawned workers both build
/// their config through this exact wiring, so a forwarded flag set cannot
/// produce a different [`TrainConfig`] on the two sides.
pub(crate) fn config_from_args(args: &Args) -> TrainConfig {
    config_from_args_with(args, &TrainConfig {
        k: 64,
        lr: 3e-3,
        seq_len: 128,
        readout_hidden: 256,
        embed_dim: 64,
        ..Default::default()
    })
}

/// Build a [`TrainConfig`] from the CLI flags, with unset flags falling
/// back to `d` — one wiring shared by `train`, `copy` and `file-lm` so a
/// new knob cannot drift between presets.
fn config_from_args_with(args: &Args, d: &TrainConfig) -> TrainConfig {
    TrainConfig {
        arch: Arch::parse(&args.str_or("arch", d.arch.name())).expect("bad --arch"),
        k: args.usize_or("k", d.k),
        density: 1.0 - args.f64_or("sparsity", 1.0 - d.density),
        method: Method::parse(&args.str_or("method", &d.method.name())).expect("bad --method"),
        lr: args.f32_or("lr", d.lr),
        batch: args.usize_or("batch", d.batch),
        seq_len: args.usize_or("seq-len", d.seq_len),
        truncation: args.usize_or("trunc", d.truncation),
        steps: args.usize_or("steps", d.steps),
        seed: args.u64_or("seed", d.seed),
        readout_hidden: args.usize_or("readout-hidden", d.readout_hidden),
        embed_dim: args.usize_or("embed-dim", d.embed_dim),
        log_every: args.usize_or("log-every", d.log_every),
        prune_to: args.get("prune-to").and_then(|v| v.parse().ok()).or(d.prune_to),
        prune_every: args.u64_or("prune-every", d.prune_every),
        prune_end_step: args.u64_or("prune-end", d.prune_end_step),
        workers: args.usize_or("workers", d.workers),
        prefetch: args.bool_or("prefetch", d.prefetch),
        checkpoint_every: args.usize_or("checkpoint-every", d.checkpoint_every),
        checkpoint_dir: args
            .get("checkpoint-dir")
            .map(PathBuf::from)
            .or_else(|| d.checkpoint_dir.clone()),
        checkpoint_keep: args.usize_or("checkpoint-keep", d.checkpoint_keep),
        resume_from: args.get("resume").map(PathBuf::from).or_else(|| d.resume_from.clone()),
        kernel: crate::sparse::KernelChoice::parse(&args.str_or("kernel", d.kernel.name()))
            .expect("bad --kernel (auto|scalar|simd|avx512|neon)"),
        ..d.clone()
    }
}

fn print_run(res: &TrainResult) {
    let mut tbl = Table::new(&["x", "train bpc", "valid bpc", "aux"]);
    for p in &res.curve {
        tbl.row(&[p.x.to_string(), f3(p.train_bpc), f3(p.valid_bpc), f2(p.aux)]);
    }
    tbl.print();
    println!(
        "tracking: {:.0} flops/step, {} floats; tokens seen: {}",
        res.tracking_flops_per_step, res.tracking_memory_floats, res.tokens_seen
    );
}

/// Run `f` over `items` on scoped threads (bounded by available cores).
/// Uses `std::thread::scope` (stable since 1.63) so the workspace builds
/// with zero external dependencies; a panicking worker propagates when the
/// scope joins.
fn parallel_map<T: Sync, R: Send>(items: &[T], f: impl Fn(&T) -> R + Sync) -> Vec<R> {
    let max_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let mut out: Vec<Option<R>> = Vec::new();
    for _ in items {
        out.push(None);
    }
    for chunk_start in (0..items.len()).step_by(max_threads) {
        let chunk_end = (chunk_start + max_threads).min(items.len());
        let slots = &mut out[chunk_start..chunk_end];
        let items_chunk = &items[chunk_start..chunk_end];
        std::thread::scope(|s| {
            for (slot, item) in slots.iter_mut().zip(items_chunk) {
                let fr = &f;
                s.spawn(move || {
                    *slot = Some(fr(item));
                });
            }
        });
    }
    out.into_iter().map(|r| r.expect("missing result")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_row_shapes_match_paper() {
        // GRU 128 @ 75% sparsity: the paper reports SnAp-2 J sparsity 70.9%
        // and SnAp-3 50.0%. Exact values depend on the random mask; the shape
        // (J2 sparser than J3, both below param sparsity) must hold.
        let row = table3_row(Arch::Gru, 64, 32, 0.25, 1);
        assert!(row.j2_sparsity > row.j3_sparsity, "{} vs {}", row.j2_sparsity, row.j3_sparsity);
        assert!(row.j2_sparsity < 0.75 + 1e-9);
        assert!(row.snap2_vs_bptt > row.snap1_vs_bptt);
        assert!(row.snap3_vs_bptt > row.snap2_vs_bptt);
        assert!(row.snap2_vs_rtrl < 1.0, "SnAp-2 must be cheaper than sparse RTRL");
    }

    #[test]
    fn lstm_snap1_roughly_2x_bptt() {
        // Table 3: "SnAp-1 vs BPTT" is 2x for LSTM (two state components).
        let row = table3_row(Arch::Lstm, 32, 16, 0.25, 2);
        assert!(row.snap1_vs_bptt < 2.5, "snap1/bptt = {}", row.snap1_vs_bptt);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<usize> = (0..20).collect();
        let out = parallel_map(&items, |&x| x * 2);
        assert_eq!(out, (0..20).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn average_curves_works() {
        let a = vec![(0u64, 1.0), (1, 3.0)];
        let b = vec![(0u64, 3.0), (1, 5.0)];
        let avg = average_curves(&[a, b]);
        assert_eq!(avg, vec![(0, 2.0), (1, 4.0)]);
    }
}
