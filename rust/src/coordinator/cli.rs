//! Hand-rolled CLI (the crate registry is offline; no clap). Grammar:
//!
//! ```text
//! repro <command> [--flag value]... [--switch]...
//! ```
//!
//! Flags are collected into a typed bag with defaulting accessors, so each
//! experiment declares only the knobs it uses.

use std::collections::HashMap;

#[derive(Debug, Clone)]
pub struct Args {
    pub command: String,
    flags: HashMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args, String> {
        if argv.is_empty() {
            return Err("missing command".into());
        }
        let command = argv[0].clone();
        let mut flags = HashMap::new();
        let mut i = 1;
        while i < argv.len() {
            let a = &argv[i];
            let Some(name) = a.strip_prefix("--") else {
                return Err(format!("expected --flag, got '{a}'"));
            };
            if let Some((k, v)) = name.split_once('=') {
                flags.insert(k.to_string(), v.to_string());
                i += 1;
            } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                flags.insert(name.to_string(), argv[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        }
        Ok(Args { command, flags })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f32_or(&self, key: &str, default: f32) -> f32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).map(|v| v == "true" || v == "1" || v == "yes").unwrap_or(default)
    }

    /// Every parsed flag as `(key, value)` pairs, **sorted by key**. The
    /// shard coordinator forwards its whole flag set to the worker
    /// processes it spawns; the sort makes the forwarded command line — and
    /// therefore the workers' derived config — deterministic (HashMap
    /// iteration order is not).
    pub fn flags_sorted(&self) -> Vec<(String, String)> {
        let mut out: Vec<(String, String)> =
            self.flags.iter().map(|(k, v)| (k.clone(), v.clone())).collect();
        out.sort();
        out
    }

    /// Comma-separated list flag.
    pub fn list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.get(key) {
            Some(v) => {
                v.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect()
            }
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }
}

pub const USAGE: &str = "\
snap-rtrl reproduction of 'A Practical Sparse Approximation for Real Time
Recurrent Learning' (Menick et al., 2020).

USAGE: repro <command> [--flag value]...

Experiment commands (one per paper table/figure):
  table1   Asymptotic + measured cost model          [--k --t --sparsity]
  fig3     Char-LM learning curves, dense & sparse   [--side dense|sparse --steps --k --batch --lr]
  table2   BPC vs sparsity at constant params (=fig4)[--steps --base-k --max-mult]
  table3   Empirical FLOPs & Jacobian sparsity       [--input-dim]
  table4   SnAp approximation quality (=fig6)        [--steps --checkpoints]
  fig5     Copy-task curriculum curves               [--arch --sparsity --methods --tokens --seeds]

Training commands:
  train    Char-LM single run    [--method --arch --k --sparsity --steps --lr --trunc --batch
                                  --dataset --workers --prefetch --kernel --checkpoint-every
                                  --resume]
  copy     Copy-task single run  [--method --arch --k --sparsity --steps --lr --trunc --batch
                                  --workers --prefetch --kernel --checkpoint-every --resume]
  file-lm  File-corpus preset: end-to-end char-LM over --dataset (required), writing
           results/file_lm_metrics.json + file_lm_curve.csv — the CI dataset-smoke job
           [--steps --k --batch --workers --seq-len --kernel --checkpoint-every --resume]

Checkpoint / resume (training commands; online runs must survive a kill):
  --checkpoint-every N  snapshot the FULL training state after every N steps (0 = off,
                        the default): theta, readout, Adam moments, every lane's
                        tracking state (SnAp/RFLO influence values + pattern
                        fingerprint, dense J for RTRL, UORO's rank-1 factors + sign
                        stream), every RNG stream, the data cursor, curriculum and
                        learning curve. Requires --checkpoint-dir.
  --checkpoint-dir P    directory for ckpt-step<N>.bin files. Writes are atomic
                        (write-then-rename), so a kill mid-write never leaves a torn
                        checkpoint.
  --checkpoint-keep K   bounded retention: keep only the newest K snapshots (default 3).
  --resume PATH         resume from a checkpoint file, or from the highest-step
                        checkpoint in a directory. The resumed run is BITWISE
                        identical to one that was never interrupted — same loss
                        curve, same final theta — for any --workers/--prefetch/spawn
                        combination (enforced by rust/tests/checkpoint_resume.rs and
                        the CI resume-smoke job). The config must match the
                        checkpoint (method, arch, k, seed, ...); mismatches are
                        refused with the offending field named.
  On-disk format: versioned, length-prefixed binary with an FNV-1a-64 payload
  checksum (magic SNAPRTRL; see rust/src/train/checkpoint.rs). Corrupt or
  truncated files and version bumps fail with named errors, never a panic.
  BPTT is resumable at flushed update boundaries only (always true where the
  drivers checkpoint); all forward-mode methods resume at any update boundary.

Dataset selection (char-LM commands: train, fig3, file-lm):
  --dataset SPEC  where SPEC is one of
                    synthetic[:BYTES[:SEED]]  deterministic Markov corpus (default:
                                              synthetic:200000:1234)
                    file:PATH                 stream one text/byte file; --valid-frac
                                              (default 0.05) splits the tail off for
                                              validation
                    wikitext-dir:DIR          stream a WikiText-style directory holding
                                              wiki.{train,valid,test}.tokens shards.
                                              Point it at an extracted WikiText-103
                                              download (wikitext-103-v1.zip, ~516 MB of
                                              wiki.train.tokens) for the paper's §5.1/§5.3
                                              workload: repro train --dataset
                                              wikitext-dir:/data/wikitext-103
  --lowercase B   byte-level ASCII lowercasing at read time (default false: passthrough)
  --valid-frac F  validation fraction for single-file datasets (default 0.05)
  --corpus PATH   legacy alias for --dataset file:PATH
  File-backed datasets stream in bounded chunks (1 MiB x 8 resident by default) — no
  whole-file load — and training is bitwise identical to an in-memory corpus of the
  same bytes for any --workers/--prefetch/spawn combination.

CI commands:
  bench-gate  Diff a BENCH_*.json against a committed baseline; fails on throughput
              regression beyond tolerance  [--baseline --current --tolerance 0.25
              --normalize --strict]  (see rust/benches/baselines/README.md)
  audit       Static analysis of this repo's own source: hot-path allocation lint,
              unsafe audit, determinism lint, serde-format guard, SIMD containment
              (std::arch / #[target_feature] only in rust/src/sparse/simd.rs, and
              only behind runtime feature detection with a scalar fallback). Exits
              nonzero on any finding (path:line: [rule] message)  [--root --json
              --self-test --repin-serde]
              Annotation grammar (line comments only):
                // audit: hot-path            the next {...} block is allocation-free
                // audit: allow(RULE) REASON  silence RULE on this line + the next
              Allowlists live in rust/audit/*.allow; the serde-format pin in
              rust/audit/serde_format.pin (refresh with --repin-serde AFTER bumping
              CHECKPOINT_VERSION). See rust/src/analysis/ for the rule definitions.

Throughput knobs (training results are bitwise identical for any setting):
  --kernel K      sparse-kernel implementation every DynJacobian product,
                  fused influence update and gate-blocked refresh dispatches
                  through, resolved ONCE at startup and logged to stderr
                  (train, copy, file-lm, serve, shard-worker, step_costs bench):
                    auto    (default) the widest backend the CPU supports:
                            avx512 > simd > neon > scalar
                    scalar  portable reference kernels
                    simd    gate-blocked AVX2/FMA kernels (scalar fallback if
                            the CPU lacks them)
                    avx512  16-wide AVX-512F kernels (needs an AVX-512 CPU and
                            a toolchain >= 1.89; falls back to simd otherwise)
                    neon    aarch64 NEON kernels (scalar fallback off-arm)
                  Checkpoints do not record the kernel (blobs are kernel-
                  agnostic); backends agree to ~1e-6 per step, so keep the
                  flag consistent across a checkpoint lineage when bitwise
                  reproducibility matters. Unsafe/intrinsics stay confined to
                  rust/src/sparse/simd.rs (enforced by the audit `simd` rule).
  --workers N     step the minibatch lanes on N threads from a persistent
                  worker pool (0 = all cores; default 1). The one exception:
                  Copy with --trunc > 0 and N > 1 switches to the batched-
                  online update schedule (a different training regime).
  --prefetch B    async double-buffered data feeding (default true): a
                  prefetch thread materialises the next minibatch's crops /
                  Copy sequences while the workers compute the current one.
                  --prefetch false generates inline at each step boundary.

Serving (session-multiplexed online adaptation):
  serve    Run the online-adaptation server under a deterministic synthetic
           traffic driver: thousands of independent stateful sessions stepped
           in cross-session batches through the shared training stepper, with
           LRU residency spilling cold sessions to disk and restoring them
           bitwise.  [--sessions 1000 --resident 128 --lanes 32 --workers 1
           --ticks 64 --seed 1 --arch gru --method snap-1 --k 32 --lr 1e-3
           --embed-dim 16 --readout-hidden 32 --kernel auto --queue-cap 4*lanes
           --spill-dir results/serve_spill --curves-dir DIR
           --checkpoint PATH --resume PATH --kill-after T --bench-json PATH]
           Session lifecycle: admit (derived from (seed, id)) -> submit
           (bounded queue; full => request shed with a named error) -> tick
           (check out <= --lanes sessions, one shared online weight update) ->
           LRU evict <-> bitwise restore -> checkpoint/resume.
           Spill layout: <spill-dir>/session-<id>.bin, one versioned blob per
           cold session, written atomically. --checkpoint snapshots the whole
           server (tick counter + shared weights/optimizers + queue + every
           session blob); --resume rebuilds it and continues bitwise —
           --kill-after T exercises exactly that mid-traffic (CI serve-smoke).
           --curves-dir writes one per-session loss-curve CSV per session;
           --bench-json writes p50/p99 batched-step latency + session-steps/s
           (BENCH_serve.json, gated by bench-gate).

Lane sharding (multi-process training):
  shard-coordinator  Run a train/copy workload with the lane computation
           sharded across worker processes. The coordinator keeps the whole
           driver — data sampling, evaluation, the ordered lane-order
           gradient reduction, optimizer updates, checkpointing — and ships
           only lane stepping to the workers, so ANY sharding (1, 2, 4, ...
           processes) is bitwise identical to the single-process `train` /
           `copy` run: same curve, same final theta, byte-identical
           --dump-state files.
           [--task char-lm|copy --shard-workers 2 --reshard-workers N
            --shard-attempts 3 --shard-timeout-secs 30 --shard-retries 3
            --die-at-step 0 --dump-state PATH
            + every train/copy flag (--method --arch --k --batch --steps
              --dataset --checkpoint-every --checkpoint-dir --resume ...)]
           Sharded Copy runs require --trunc 0 (full unroll): truncated Copy
           schedules update theta mid-sequence and are refused with a named
           error.
           Elastic resharding: a worker that stops answering (crash, kill,
           timeout after --shard-retries reads of --shard-timeout-secs) is
           declared dead; the coordinator tears the fleet down and retries —
           up to --shard-attempts times, with --reshard-workers processes —
           resuming from the newest checkpoint in --checkpoint-dir when one
           exists (fresh otherwise). Checkpoints hold per-lane state blobs
           independent of the lane->process mapping, so a 2-wide run killed
           mid-flight resumes 4-wide bitwise. --die-at-step N is the chaos
           knob: worker 0 exits abruptly at minibatch N on the first attempt
           (used by tests/executor_determinism.rs and CI shard-smoke).
  shard-worker  One worker process (spawned by shard-coordinator; not for
           manual use). Owns lanes [--lane-lo, --lane-hi) of the minibatch,
           replays the run's deterministic construction from the forwarded
           flags, connects back over --connect and answers the coordinator's
           message loop.
           Wire protocol & versioning: every message is one length-prefixed
           frame carrying the standard SNAPRTRL container (version =
           SHARD_WIRE_VERSION, FNV-1a-64 payload checksum). Any layout or
           message-set change bumps SHARD_WIRE_VERSION, so mixed-build
           fleets refuse each other on the FIRST frame with a named version
           error; corrupt frames fail the checksum, never desynchronize. The
           handshake also compares the worker's full derived ConfigKey
           against the coordinator's and refuses drift field by field.
  --dump-state PATH  (train, copy, shard-coordinator) write a canonical
           binary digest of the finished run (theta + readout bits, loss
           curve, tokens, curriculum level) for byte-for-byte comparison
           between runs (`cmp` in CI shard-smoke).

Runtime commands:
  aot-demo Run the AOT-compiled GRU/SnAp-1 step from the PJRT runtime
  info     Print build/config information

All experiments write CSVs into results/ (override with SNAP_RTRL_RESULTS).
Scaled-down defaults reproduce the paper's *shapes* in minutes; raise --steps
/ --tokens for closer replication.
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(&s.iter().map(|x| x.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn parses_flags_and_switches() {
        let a = parse(&["fig3", "--steps", "100", "--side=sparse", "--verbose"]);
        assert_eq!(a.command, "fig3");
        assert_eq!(a.usize_or("steps", 1), 100);
        assert_eq!(a.str_or("side", "dense"), "sparse");
        assert!(a.bool_or("verbose", false));
        assert_eq!(a.usize_or("missing", 7), 7);
    }

    #[test]
    fn list_flag() {
        let a = parse(&["fig5", "--methods", "bptt,snap-1, snap-2"]);
        assert_eq!(a.list_or("methods", &[]), vec!["bptt", "snap-1", "snap-2"]);
        assert_eq!(a.list_or("other", &["x"]), vec!["x"]);
    }

    #[test]
    fn rejects_bare_args() {
        let e = Args::parse(&["cmd".into(), "oops".into()]);
        assert!(e.is_err());
    }
}
