//! Bench-regression gate: `repro bench-gate` diffs a fresh `BENCH_*.json`
//! (written by the bench binaries via `benchutil::write_bench_json`) against
//! a committed baseline under `rust/benches/baselines/` and fails on a
//! throughput regression beyond the tolerance (CI default: 25% tokens/sec).
//!
//! ## Matching
//!
//! Rows are matched by their **identity fields** — everything except the
//! measurement fields (`tokens_per_sec`, `wall_s`, `speedup_vs_workers1`,
//! `pool_gain`, `final_level`, `crops_per_sec`, `mb_per_sec`). Baseline rows
//! missing from the current run are skipped with a warning (runner core
//! counts prune worker sweeps); current rows absent from the baseline are
//! new coverage and ignored. At least one row must match or the gate fails.
//!
//! ## Normalisation
//!
//! Absolute tokens/sec are machine-dependent, and CI runners are
//! heterogeneous. With `--normalize true` every row's metric is divided by
//! the **median metric of its own file** before comparison, so the gate
//! fires on *relative* regressions (a mode, worker count or method getting
//! slower than its peers) and is immune to a uniformly faster/slower
//! runner. The trade-off — a perfectly uniform slowdown of every row is
//! invisible — is accepted: absolute trajectories are tracked by the
//! uploaded artifacts. Run without `--normalize` locally, where baseline
//! and current come from the same machine.
//!
//! ## Arming
//!
//! A baseline whose `meta` carries `"provisional": true` (the synthesized
//! seed baselines committed before any CI run) downgrades failures to
//! warnings so invented numbers cannot block unrelated PRs. The bench
//! binaries never emit that flag, so overwriting the baseline with a real
//! CI artifact **automatically arms the gate**. `--strict true` treats a
//! provisional baseline as armed anyway. The CI step is skipped entirely
//! when the PR carries the `perf-override` label (the documented escape
//! hatch for intentional trade-offs).

use crate::coordinator::cli::Args;
use crate::coordinator::report::Table;
use crate::errors::{Context as _, Result};

// ---------------------------------------------------------------------------
// Minimal JSON (offline: no serde). Covers everything write_bench_json emits
// plus the standard scalar/array/object grammar.
// ---------------------------------------------------------------------------

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&mut self) -> Result<u8> {
        self.skip_ws();
        self.b.get(self.i).copied().context("unexpected end of JSON input")
    }

    fn eat(&mut self, want: u8) -> Result<()> {
        let got = self.peek()?;
        crate::ensure!(
            got == want,
            "expected '{}' at byte {}, found '{}'",
            want as char,
            self.i,
            got as char
        );
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn literal(&mut self, word: &str, out: Json) -> Result<Json> {
        self.skip_ws();
        crate::ensure!(
            self.b[self.i..].starts_with(word.as_bytes()),
            "bad literal at byte {}",
            self.i
        );
        self.i += word.len();
        Ok(out)
    }

    fn number(&mut self) -> Result<Json> {
        self.skip_ws();
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .context("non-UTF8 number")?;
        let v: f64 = text
            .parse()
            .ok()
            .with_context(|| format!("bad JSON number '{text}' at byte {start}"))?;
        Ok(Json::Num(v))
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = *self.b.get(self.i).context("unterminated JSON string")?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = *self.b.get(self.i).context("truncated escape")?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            crate::ensure!(self.i + 4 <= self.b.len(), "truncated \\u escape");
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .ok()
                                .context("non-UTF8 \\u escape")?;
                            let code = u32::from_str_radix(hex, 16)
                                .ok()
                                .with_context(|| format!("bad \\u escape '{hex}'"))?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        other => crate::bail!("unknown escape '\\{}'", other as char),
                    }
                }
                _ => {
                    // Copy raw bytes (UTF-8 multibyte sequences pass through).
                    let start = self.i - 1;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .ok()
                            .context("non-UTF8 JSON string")?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                other => crate::bail!("expected ',' or ']' in array, found '{}'", other as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut fields = Vec::new();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.eat(b':')?;
            fields.push((key, self.value()?));
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(fields));
                }
                other => crate::bail!("expected ',' or '}}' in object, found '{}'", other as char),
            }
        }
    }
}

/// Parse a JSON document (the subset/superset needed for BENCH files).
pub fn parse_json(text: &str) -> Result<Json> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    let v = p.value()?;
    p.skip_ws();
    crate::ensure!(p.i == p.b.len(), "trailing bytes after JSON document at {}", p.i);
    Ok(v)
}

// ---------------------------------------------------------------------------
// Gate
// ---------------------------------------------------------------------------

/// Fields that carry measurements, not identity.
const MEASUREMENT_KEYS: &[&str] = &[
    "tokens_per_sec",
    "wall_s",
    "speedup_vs_workers1",
    "pool_gain",
    "final_level",
    "crops_per_sec",
    "mb_per_sec",
    "steps_per_sec",
    "ns_per_step",
    "tracking_flops",
    "tracking_floats",
    "p50_us",
    "p99_us",
];

/// Metric candidates, in preference order (all higher-is-better).
const METRIC_KEYS: &[&str] = &["tokens_per_sec", "crops_per_sec", "mb_per_sec", "steps_per_sec"];

/// One BENCH_*.json file, decoded.
pub struct BenchFile {
    pub bench: String,
    /// `meta.provisional == true`: synthesized seed baseline, warn-only.
    pub provisional: bool,
    /// `(identity, metric)` per row that has a metric.
    pub rows: Vec<(String, f64)>,
}

impl BenchFile {
    pub fn load(path: &str) -> Result<BenchFile> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading bench file '{path}'"))?;
        let doc = parse_json(&text).map_err(|e| e.context(format!("parsing bench file '{path}'")))?;
        let bench = doc
            .get("bench")
            .and_then(Json::as_str)
            .with_context(|| format!("'{path}' has no \"bench\" field"))?
            .to_string();
        let provisional = matches!(
            doc.get("meta").and_then(|m| m.get("provisional")),
            Some(Json::Bool(true))
        );
        let rows_json = doc
            .get("rows")
            .and_then(Json::as_arr)
            .with_context(|| format!("'{path}' has no \"rows\" array"))?;
        let mut rows = Vec::new();
        for row in rows_json {
            if let (Some(id), Some(metric)) = (row_identity(row), row_metric(row)) {
                rows.push((id, metric));
            }
        }
        Ok(BenchFile { bench, provisional, rows })
    }
}

/// Identity string: every non-measurement field, sorted by key so field
/// order in the file cannot break matching.
///
/// `kernel` is part of identity: a scalar row must never be compared
/// against a SIMD row of the same configuration (that silent cross-compare
/// would read the SIMD speedup as a scalar "regression", or vice versa).
/// The same holds for every backend value step_costs emits (`scalar`,
/// `simd`, `avx512`, `neon`) and for the `update` field of its
/// fused-vs-two-pass rows — any non-measurement field lands in the
/// identity, so new axes never cross-compare. Rows written before the
/// kernel sweep existed carry no `kernel` field; they measured the scalar
/// code paths, so the implicit `kernel=scalar` is injected here to keep
/// pre-sweep baselines matchable against the scalar half of a post-sweep
/// run.
fn row_identity(row: &Json) -> Option<String> {
    let Json::Obj(fields) = row else { return None };
    let mut parts: Vec<String> = fields
        .iter()
        .filter(|(k, _)| !MEASUREMENT_KEYS.contains(&k.as_str()))
        .map(|(k, v)| match v {
            Json::Str(s) => format!("{k}={s}"),
            Json::Num(n) => format!("{k}={n}"),
            other => format!("{k}={other:?}"),
        })
        .collect();
    if !fields.iter().any(|(k, _)| k == "kernel") {
        parts.push("kernel=scalar".to_string());
    }
    parts.sort();
    Some(parts.join(" "))
}

fn row_metric(row: &Json) -> Option<f64> {
    METRIC_KEYS
        .iter()
        .find_map(|k| row.get(k).and_then(Json::as_f64))
        .filter(|v| v.is_finite() && *v > 0.0)
}

/// One compared row.
pub struct GateRow {
    pub identity: String,
    pub baseline: f64,
    pub current: f64,
    /// Fractional drop of the (possibly normalised) metric; negative = faster.
    pub drop: f64,
    pub failed: bool,
}

/// The gate's verdict over all matched rows.
pub struct GateOutcome {
    pub rows: Vec<GateRow>,
    pub skipped_missing: usize,
    pub tolerance: f64,
    pub normalized: bool,
}

impl GateOutcome {
    pub fn failures(&self) -> impl Iterator<Item = &GateRow> {
        self.rows.iter().filter(|r| r.failed)
    }
}

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).expect("finite metrics"));
    xs[xs.len() / 2]
}

/// Compare `current` against `baseline`: a matched row fails when its
/// (normalised) metric dropped by more than `tolerance`.
pub fn gate(
    baseline: &BenchFile,
    current: &BenchFile,
    tolerance: f64,
    normalize: bool,
) -> Result<GateOutcome> {
    crate::ensure!(
        baseline.bench == current.bench,
        "bench mismatch: baseline is '{}', current is '{}'",
        baseline.bench,
        current.bench
    );
    let mut matched: Vec<(String, f64, f64)> = Vec::new();
    let mut skipped_missing = 0usize;
    for (id, base_v) in &baseline.rows {
        match current.rows.iter().find(|(cid, _)| cid == id) {
            Some((_, cur_v)) => matched.push((id.clone(), *base_v, *cur_v)),
            None => skipped_missing += 1,
        }
    }
    crate::ensure!(
        !matched.is_empty(),
        "no comparable rows between baseline and current '{}' output \
         (identity fields changed? regenerate the baseline)",
        current.bench
    );
    let (base_ref, cur_ref) = if normalize {
        (
            median(matched.iter().map(|(_, b, _)| *b).collect()),
            median(matched.iter().map(|(_, _, c)| *c).collect()),
        )
    } else {
        (1.0, 1.0)
    };
    let rows = matched
        .into_iter()
        .map(|(identity, baseline_v, current_v)| {
            let rel = (current_v / cur_ref) / (baseline_v / base_ref);
            let drop = 1.0 - rel;
            GateRow {
                identity,
                baseline: baseline_v,
                current: current_v,
                drop,
                failed: drop > tolerance,
            }
        })
        .collect();
    Ok(GateOutcome { rows, skipped_missing, tolerance, normalized: normalize })
}

/// Turn an outcome into a CLI exit: provisional baselines warn, armed
/// baselines fail with the worst rows listed.
pub fn enforce(outcome: &GateOutcome, provisional: bool, strict: bool) -> Result<()> {
    let failures: Vec<&GateRow> = outcome.failures().collect();
    if failures.is_empty() {
        return Ok(());
    }
    if provisional && !strict {
        println!(
            "\nWARNING: {} row(s) regressed beyond {:.0}%, but the baseline is marked \
             provisional (synthesized numbers). Refresh it from a CI bench-smoke artifact \
             to arm the gate.",
            failures.len(),
            outcome.tolerance * 100.0
        );
        return Ok(());
    }
    let worst: Vec<String> = failures
        .iter()
        .map(|r| {
            format!(
                "  {:.1}% slower: {} ({:.0} -> {:.0})",
                r.drop * 100.0,
                r.identity,
                r.baseline,
                r.current
            )
        })
        .collect();
    crate::bail!(
        "bench regression gate failed: {} row(s) regressed beyond {:.0}%{}:\n{}\n\
         If the slowdown is an accepted trade-off, apply the 'perf-override' PR label \
         (skips this step) or refresh rust/benches/baselines/.",
        failures.len(),
        outcome.tolerance * 100.0,
        if outcome.normalized { " (median-normalized)" } else { "" },
        worst.join("\n")
    )
}

/// CLI entry: `repro bench-gate --baseline B --current C
/// [--tolerance 0.25] [--normalize B] [--strict B]`.
pub fn run_bench_gate(args: &Args) -> Result<()> {
    let baseline_path = args.get("baseline").context("bench-gate needs --baseline <path>")?;
    let current_path = args.get("current").context("bench-gate needs --current <path>")?;
    let tolerance = args.f64_or("tolerance", 0.25);
    let normalize = args.bool_or("normalize", false);
    let strict = args.bool_or("strict", false);

    let baseline = BenchFile::load(baseline_path)?;
    let current = BenchFile::load(current_path)?;
    let outcome = gate(&baseline, &current, tolerance, normalize)?;

    println!(
        "# bench-gate '{}' — {} rows compared, {} baseline rows unmatched, tolerance {:.0}%{}{}",
        current.bench,
        outcome.rows.len(),
        outcome.skipped_missing,
        tolerance * 100.0,
        if normalize { ", median-normalized" } else { "" },
        if baseline.provisional { ", PROVISIONAL baseline" } else { "" },
    );
    let mut tbl = Table::new(&["row", "baseline", "current", "drop", "verdict"]);
    for r in &outcome.rows {
        tbl.row(&[
            r.identity.clone(),
            format!("{:.0}", r.baseline),
            format!("{:.0}", r.current),
            format!("{:+.1}%", r.drop * 100.0),
            if r.failed { "FAIL".into() } else { "ok".into() },
        ]);
    }
    tbl.print();
    enforce(&outcome, baseline.provisional, strict)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchutil::{write_bench_json, JsonObj};

    fn file(rows: &[(&str, u64, f64)], provisional: bool) -> BenchFile {
        let rows = rows
            .iter()
            .map(|(mode, workers, tps)| {
                (format!("sweep=batch mode={mode} workers={workers}"), *tps)
            })
            .collect();
        BenchFile { bench: "lane_throughput".into(), provisional, rows }
    }

    #[test]
    fn parses_benchutil_output_roundtrip() {
        let dir = std::env::temp_dir().join("snap_rtrl_benchgate_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bench.json");
        let path = path.to_str().unwrap().to_string();
        let meta = JsonObj::new().int("k", 48).str("note", "quote \" and\nnewline");
        let rows = vec![
            JsonObj::new().str("mode", "persistent").int("workers", 2).num("tokens_per_sec", 123.5),
            JsonObj::new().str("mode", "per-section").int("workers", 2).num("tokens_per_sec", 99.0),
        ];
        write_bench_json(&path, "lane_throughput", &meta, &rows).unwrap();
        let parsed = BenchFile::load(&path).unwrap();
        assert_eq!(parsed.bench, "lane_throughput");
        assert!(!parsed.provisional);
        assert_eq!(parsed.rows.len(), 2);
        // No "kernel" field in the row: the implicit scalar tag is injected.
        assert_eq!(parsed.rows[0].0, "kernel=scalar mode=persistent workers=2");
        assert_eq!(parsed.rows[0].1, 123.5);
    }

    #[test]
    fn no_samples_serve_row_parses_and_is_skipped() {
        // A `--ticks 0` serve run records no latencies; `run_serve_cli` now
        // emits 0.0 metrics with a `no_samples` marker instead of NaN. The
        // file must stay parseable and the degenerate row must simply be
        // excluded from comparable rows, not fail the load.
        let dir = std::env::temp_dir().join("snap_rtrl_benchgate_no_samples_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_serve.json");
        let path = path.to_str().unwrap().to_string();
        let meta = JsonObj::new().str("method", "snap-1").int("ticks", 0);
        let rows = vec![
            JsonObj::new()
                .int("sessions", 8)
                .int("lanes", 4)
                .num("p50_us", 0.0)
                .num("p99_us", 0.0)
                .num("steps_per_sec", 0.0)
                .int("no_samples", 1),
            JsonObj::new()
                .int("sessions", 8)
                .int("lanes", 8)
                .num("p50_us", 12.5)
                .num("p99_us", 31.0)
                .num("steps_per_sec", 4000.0),
        ];
        write_bench_json(&path, "serve", &meta, &rows).unwrap();
        let parsed = BenchFile::load(&path).unwrap();
        assert_eq!(parsed.bench, "serve");
        // Only the real measurement survives as a comparable row.
        assert_eq!(parsed.rows.len(), 1);
        assert_eq!(parsed.rows[0].0, "kernel=scalar lanes=8 sessions=8");
        assert_eq!(parsed.rows[0].1, 4000.0);
    }

    #[test]
    fn kernel_field_is_identity_and_defaults_to_scalar() {
        let row = |kernel: Option<&str>| {
            let mut fields = vec![
                ("method".to_string(), Json::Str("snap-2".into())),
                ("steps_per_sec".to_string(), Json::Num(100.0)),
            ];
            if let Some(k) = kernel {
                fields.push(("kernel".to_string(), Json::Str(k.into())));
            }
            Json::Obj(fields)
        };
        // Pre-sweep rows (no field) match the scalar half of a new run...
        assert_eq!(row_identity(&row(None)).unwrap(), row_identity(&row(Some("scalar"))).unwrap());
        // ...and never the SIMD half: scalar-vs-SIMD A/B rows are distinct
        // identities, so the gate cannot silently cross-compare them.
        assert_ne!(
            row_identity(&row(Some("scalar"))).unwrap(),
            row_identity(&row(Some("simd"))).unwrap()
        );
        assert_eq!(row_identity(&row(Some("simd"))).unwrap(), "kernel=simd method=snap-2");
    }

    #[test]
    fn parse_json_handles_scalars_arrays_escapes() {
        assert_eq!(parse_json("null").unwrap(), Json::Null);
        assert_eq!(parse_json(" [1, 2.5, -3e2] ").unwrap().as_arr().unwrap().len(), 3);
        let s = parse_json(r#""a\"bA\n""#).unwrap();
        assert_eq!(s.as_str().unwrap(), "a\"bA\n");
        assert!(parse_json("{bad}").is_err());
        assert!(parse_json("[1,]").is_err());
        assert!(parse_json("{} trailing").is_err());
    }

    #[test]
    fn deliberate_slowdown_trips_the_gate() {
        // The acceptance demonstration: one row 40% slower than baseline
        // must fail at 25% tolerance, in both absolute and normalized modes.
        // Several unchanged rows keep the median anchored, as in the real
        // sweeps (a lone changed row cannot drag the reference with it).
        let base = file(
            &[
                ("persistent", 1, 1000.0),
                ("persistent", 2, 2000.0),
                ("persistent", 4, 3000.0),
                ("persistent", 8, 4000.0),
                ("persistent", 16, 5000.0),
            ],
            false,
        );
        let slow = file(
            &[
                ("persistent", 1, 1000.0),
                ("persistent", 2, 2000.0),
                ("persistent", 4, 3000.0),
                ("persistent", 8, 4000.0),
                ("persistent", 16, 3000.0), // 40% down
            ],
            false,
        );
        for normalize in [false, true] {
            let outcome = gate(&base, &slow, 0.25, normalize).unwrap();
            let failures: Vec<_> = outcome.failures().collect();
            assert_eq!(failures.len(), 1, "normalize={normalize}");
            assert!(failures[0].identity.contains("workers=16"));
            let e = enforce(&outcome, false, false).unwrap_err();
            assert!(e.to_string().contains("perf-override"), "{e}");
        }
    }

    #[test]
    fn small_variance_passes() {
        let base = file(&[("persistent", 1, 1000.0), ("persistent", 4, 3000.0)], false);
        let cur = file(&[("persistent", 1, 900.0), ("persistent", 4, 2800.0)], false);
        let outcome = gate(&base, &cur, 0.25, false).unwrap();
        assert_eq!(outcome.failures().count(), 0);
        enforce(&outcome, false, false).unwrap();
    }

    #[test]
    fn normalization_is_immune_to_a_uniformly_slower_host() {
        // Every row exactly 2x slower (a weaker runner): absolute mode
        // fails, normalized mode passes — the property CI relies on.
        let base = file(&[("persistent", 1, 1000.0), ("persistent", 4, 3000.0)], false);
        let halved = file(&[("persistent", 1, 500.0), ("persistent", 4, 1500.0)], false);
        assert_eq!(gate(&base, &halved, 0.25, false).unwrap().failures().count(), 2);
        assert_eq!(gate(&base, &halved, 0.25, true).unwrap().failures().count(), 0);
    }

    #[test]
    fn provisional_baseline_warns_instead_of_failing() {
        let base = file(&[("persistent", 1, 1000.0)], true);
        let slow = file(&[("persistent", 1, 100.0)], true);
        let outcome = gate(&base, &slow, 0.25, false).unwrap();
        assert_eq!(outcome.failures().count(), 1);
        enforce(&outcome, true, false).unwrap(); // provisional: warn only
        assert!(enforce(&outcome, true, true).is_err()); // --strict arms it
        assert!(enforce(&outcome, false, false).is_err()); // refreshed: armed
    }

    #[test]
    fn missing_rows_are_skipped_but_empty_match_fails() {
        let base = file(&[("persistent", 1, 1000.0), ("persistent", 8, 5000.0)], false);
        let cur = file(&[("persistent", 1, 1000.0)], false);
        let outcome = gate(&base, &cur, 0.25, false).unwrap();
        assert_eq!(outcome.rows.len(), 1);
        assert_eq!(outcome.skipped_missing, 1);
        let none = file(&[("other-mode", 1, 1000.0)], false);
        assert!(gate(&base, &none, 0.25, false).is_err());
    }
}
