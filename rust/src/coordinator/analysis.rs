//! Approximation-quality analysis (paper §5.3, Table 4 + Figure 6).
//!
//! Trains a small sparse GRU with full BPTT on a *fixed-length* Copy variant,
//! and at requested checkpoints runs one full sequence while tracking the
//! exact influence matrix with RTRL, then measures how much of the influence
//! mass falls inside the SnAp-1 / SnAp-2 patterns.

use crate::cells::{Arch, Cell};
use crate::data::copy::{CopySeq, COPY_CLASSES, COPY_VOCAB};
use crate::grad::{Bptt, GradAlgo, Rtrl};
use crate::models::{Embedding, Readout, ReadoutCache};
use crate::opt::{Adam, Optimizer};
use crate::sparse::pattern::{snap_pattern, Pattern};
use crate::tensor::matrix::Matrix;
use crate::tensor::rng::Pcg32;

/// Mass statistics of the exact influence matrix w.r.t. a pattern split.
#[derive(Debug, Clone)]
pub struct InfluenceStats {
    pub step: u64,
    /// mean |J_ij| over entries kept by SnAp-1 / by SnAp-2 / ignored by both
    pub mean_kept_snap1: f64,
    pub mean_kept_snap2: f64,
    pub mean_ignored: f64,
    /// fraction of total |J| mass inside each pattern
    pub mass_frac_snap1: f64,
    pub mass_frac_snap2: f64,
}

/// Raw influence dump for the Figure 6 Hinton diagram: (i, j, |J_ij|, category)
/// with category 1 = SnAp-1, 2 = SnAp-2 \ SnAp-1, 0 = ignored.
pub type InfluenceDump = Vec<(usize, usize, f32, u8)>;

pub struct Table4Config {
    pub k: usize,
    pub density: f64,
    pub target_len: usize,
    pub lr: f32,
    pub seed: u64,
    pub checkpoints: Vec<u64>,
}

impl Default for Table4Config {
    fn default() -> Self {
        Table4Config {
            k: 8,
            density: 0.25,
            target_len: 16,
            lr: 1e-3,
            seed: 7,
            checkpoints: vec![100, 1000, 2000, 5000],
        }
    }
}

/// Run the §5.3 experiment. Returns per-checkpoint stats plus the final
/// influence dump (for fig6.csv).
pub fn run_table4(cfg: &Table4Config) -> (Vec<InfluenceStats>, InfluenceDump) {
    let mut rng = Pcg32::seeded(cfg.seed);
    let cell = Arch::Gru.build(cfg.k, COPY_VOCAB, cfg.density, &mut rng);
    let embed = Embedding::one_hot(COPY_VOCAB);
    let mut readout = Readout::new(cell.hidden_size(), 32, COPY_CLASSES, &mut rng);
    let mut theta = cell.init_params(&mut rng);
    let p = cell.num_params();
    let mut opt_rec = Adam::new(p, cfg.lr);
    let mut opt_ro = Adam::new(readout.num_params(), cfg.lr);

    let snap1 = snap_pattern(&cell.dynamics_pattern(), &cell.immediate_structure().pattern(), 1);
    let snap2 = snap_pattern(&cell.dynamics_pattern(), &cell.immediate_structure().pattern(), 2);

    let mut stats = Vec::new();
    let mut dump = InfluenceDump::new();
    let max_step = *cfg.checkpoints.iter().max().unwrap_or(&1000);

    let mut bptt = Bptt::new(cell.as_ref());
    let mut g_rec = vec![0.0f32; p];
    let mut g_ro = readout.make_grad();
    let mut cache = ReadoutCache::default();

    for step in 1..=max_step {
        // one full-BPTT training sequence (fixed target length)
        bptt.reset();
        let seq = CopySeq::generate(cfg.target_len, &mut rng);
        for (t, &tok) in seq.inputs.iter().enumerate() {
            bptt.step(&theta, embed.lookup(tok));
            if let Some(target) = seq.targets[t] {
                readout.forward(bptt.hidden(), &mut cache);
                let (_, dh) = readout.loss_and_backward(&mut cache, target, &mut g_ro);
                bptt.inject_loss(dh, &mut g_rec);
            }
        }
        bptt.flush(&theta, &mut g_rec);
        opt_rec.step(&mut theta, &mut g_rec);
        let mut delta = vec![0.0f32; g_ro.flat.len()];
        opt_ro.step(&mut delta, &mut g_ro.flat);
        readout.apply_delta(&delta);

        if cfg.checkpoints.contains(&step) {
            let j = exact_influence_after_sequence(
                cell.as_ref(),
                &theta,
                &embed,
                cfg.target_len,
                &mut rng,
            );
            let s = measure(step, &j, &snap1, &snap2);
            stats.push(s);
            if step == max_step {
                dump = dump_influence(&j, &snap1, &snap2);
            }
        }
    }
    (stats, dump)
}

/// Track the exact J with RTRL over one full sequence.
fn exact_influence_after_sequence(
    cell: &dyn Cell,
    theta: &[f32],
    embed: &Embedding,
    target_len: usize,
    rng: &mut Pcg32,
) -> Matrix {
    let mut rtrl = Rtrl::new(cell, false);
    let seq = CopySeq::generate(target_len, rng);
    for &tok in &seq.inputs {
        rtrl.step(theta, embed.lookup(tok));
    }
    rtrl.influence().clone()
}

fn measure(step: u64, j: &Matrix, snap1: &Pattern, snap2: &Pattern) -> InfluenceStats {
    let (mut s1_sum, mut s1_n) = (0.0f64, 0usize);
    let (mut s2_sum, mut s2_n) = (0.0f64, 0usize);
    let (mut ig_sum, mut ig_n) = (0.0f64, 0usize);
    let mut total = 0.0f64;
    for i in 0..j.rows() {
        for c in 0..j.cols() {
            let v = j.get(i, c).abs() as f64;
            total += v;
            if snap1.contains(i, c) {
                s1_sum += v;
                s1_n += 1;
            }
            if snap2.contains(i, c) {
                s2_sum += v;
                s2_n += 1;
            } else {
                ig_sum += v;
                ig_n += 1;
            }
        }
    }
    InfluenceStats {
        step,
        mean_kept_snap1: s1_sum / s1_n.max(1) as f64,
        mean_kept_snap2: s2_sum / s2_n.max(1) as f64,
        mean_ignored: ig_sum / ig_n.max(1) as f64,
        mass_frac_snap1: if total > 0.0 { s1_sum / total } else { 0.0 },
        mass_frac_snap2: if total > 0.0 { s2_sum / total } else { 0.0 },
    }
}

fn dump_influence(j: &Matrix, snap1: &Pattern, snap2: &Pattern) -> InfluenceDump {
    let mut out = Vec::with_capacity(j.rows() * j.cols());
    for i in 0..j.rows() {
        for c in 0..j.cols() {
            let cat = if snap1.contains(i, c) {
                1u8
            } else if snap2.contains(i, c) {
                2
            } else {
                0
            };
            out.push((i, c, j.get(i, c).abs(), cat));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table4_runs_and_mass_fractions_are_sane() {
        let cfg = Table4Config {
            k: 6,
            density: 0.25,
            target_len: 6,
            lr: 1e-3,
            seed: 3,
            checkpoints: vec![5, 20],
        };
        let (stats, dump) = run_table4(&cfg);
        assert_eq!(stats.len(), 2);
        for s in &stats {
            // SnAp-2 keeps a superset of SnAp-1's entries.
            assert!(s.mass_frac_snap2 >= s.mass_frac_snap1 - 1e-12);
            assert!((0.0..=1.0).contains(&s.mass_frac_snap1));
            assert!((0.0..=1.0).contains(&s.mass_frac_snap2));
            assert!(s.mean_kept_snap1.is_finite());
        }
        assert!(!dump.is_empty());
        // dump covers the full matrix
        let cats: std::collections::HashSet<u8> = dump.iter().map(|e| e.3).collect();
        assert!(cats.contains(&1));
    }

    #[test]
    fn kept_entries_carry_more_mass_early() {
        // Paper finding: early in training the ignored entries are small
        // compared to kept ones.
        let cfg = Table4Config {
            k: 8,
            density: 0.25,
            target_len: 8,
            lr: 1e-3,
            seed: 11,
            checkpoints: vec![50],
        };
        let (stats, _) = run_table4(&cfg);
        let s = &stats[0];
        assert!(
            s.mean_kept_snap1 > s.mean_ignored,
            "kept {} vs ignored {}",
            s.mean_kept_snap1,
            s.mean_ignored
        );
    }
}
