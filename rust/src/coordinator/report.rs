//! Report sinks: aligned-column tables on stdout (markdown-ish, matching the
//! paper's table layout) and CSV files under `results/` for the figures.

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::{Path, PathBuf};

/// Column-aligned table printer.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells.to_vec());
    }

    pub fn rows_len(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (c, h) in self.header.iter().enumerate() {
            width[c] = h.len();
        }
        for row in &self.rows {
            for (c, cell) in row.iter().enumerate() {
                width[c] = width[c].max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize], out: &mut String| {
            out.push('|');
            for (c, cell) in cells.iter().enumerate() {
                let _ = write!(out, " {:<w$} |", cell, w = width[c]);
            }
            out.push('\n');
        };
        fmt_row(&self.header, &width, &mut out);
        out.push('|');
        for w in &width {
            let _ = write!(out, "{}|", "-".repeat(w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            fmt_row(row, &width, &mut out);
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Directory for CSV outputs (created on demand).
pub fn results_dir() -> PathBuf {
    let dir = std::env::var("SNAP_RTRL_RESULTS").unwrap_or_else(|_| "results".into());
    let p = PathBuf::from(dir);
    std::fs::create_dir_all(&p).ok();
    p
}

/// Write a CSV file into results/; returns the path.
pub fn write_csv(name: &str, header: &[&str], rows: &[Vec<String>]) -> PathBuf {
    let path = results_dir().join(name);
    let mut f = std::fs::File::create(&path).expect("create csv");
    writeln!(f, "{}", header.join(",")).unwrap();
    for row in rows {
        writeln!(f, "{}", row.join(",")).unwrap();
    }
    path
}

/// Format helpers.
pub fn f2(v: f64) -> String {
    format!("{v:.2}")
}

pub fn f3(v: f64) -> String {
    format!("{v:.3}")
}

pub fn pct(v: f64) -> String {
    format!("{:.1}%", v * 100.0)
}

pub fn mult(v: f64) -> String {
    if v >= 100.0 {
        format!("{v:.0}x")
    } else if v >= 10.0 {
        format!("{v:.1}x")
    } else {
        format!("{v:.2}x")
    }
}

/// Human-readable float count (memory column).
pub fn floats_h(v: f64) -> String {
    if v >= 1e9 {
        format!("{:.2}G", v / 1e9)
    } else if v >= 1e6 {
        format!("{:.2}M", v / 1e6)
    } else if v >= 1e3 {
        format!("{:.1}k", v / 1e3)
    } else {
        format!("{v:.0}")
    }
}

pub fn exists(p: &Path) -> bool {
    p.is_file()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["method", "bpc"]);
        t.row(&["snap-1".into(), "1.55".into()]);
        t.row(&["bptt".into(), "1.50".into()]);
        let r = t.render();
        assert!(r.contains("| method |"));
        assert!(r.lines().count() == 4);
    }

    #[test]
    fn formatters() {
        assert_eq!(mult(597.4), "597x");
        assert_eq!(mult(22.13), "22.1x");
        assert_eq!(mult(1.994), "1.99x");
        assert_eq!(pct(0.750), "75.0%");
        assert_eq!(floats_h(2_500_000.0), "2.50M");
    }

    #[test]
    fn csv_written() {
        std::env::set_var("SNAP_RTRL_RESULTS", std::env::temp_dir().join("snap_csv_test"));
        let p = write_csv("t.csv", &["a", "b"], &[vec!["1".into(), "2".into()]]);
        let body = std::fs::read_to_string(&p).unwrap();
        std::env::remove_var("SNAP_RTRL_RESULTS");
        assert_eq!(body, "a,b\n1,2\n");
    }
}
