//! `repro` — the coordinator CLI. See `repro help` / coordinator::USAGE.

use snap_rtrl::coordinator::{dispatch, Args, USAGE};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        println!("{USAGE}");
        std::process::exit(2);
    }
    match Args::parse(&argv) {
        Ok(args) => {
            if let Err(e) = dispatch(&args) {
                eprintln!("error: {e:#}");
                std::process::exit(1);
            }
        }
        Err(msg) => {
            eprintln!("argument error: {msg}\n\n{USAGE}");
            std::process::exit(2);
        }
    }
}
