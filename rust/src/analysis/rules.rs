//! The audit rules. Every rule has (a) a machine-checkable definition over
//! the stripped source view, (b) an escape hatch that requires a written
//! reason, and (c) a seeded-violation self-test in [`super::selftest`].
//!
//! | id            | checks                                                    |
//! |---------------|-----------------------------------------------------------|
//! | `alloc`       | no allocating/densifying calls inside hot-path regions    |
//! | `coverage`    | required files carry at least one hot-path region         |
//! | `unsafe`      | unsafe stays in allowlisted modules, with SAFETY comments |
//! | `determinism` | no HashMap/HashSet outside allowlisted sites              |
//! | `serde-format`| checkpoint blob layout changes require a version bump     |
//! | `simd`        | SIMD intrinsics stay in the kernel module, behind a guard |
//! | `directive`   | `// audit:` comments themselves parse                     |

use super::report::Finding;
use super::scanner::{Directive, SourceFile};
use super::{AllowEntry, AuditConfig};
use crate::runtime::serde::Fnv64;

/// Tokens banned inside `// audit: hot-path` regions: everything that
/// allocates, frees, densifies a sparse structure, or makes a syscall. The
/// tracking step's allocation-freedom (PR 5) is a contract, not a bench
/// artifact.
pub const BANNED_HOT: &[&str] = &[
    "Vec::new",
    "vec!",
    "to_vec",
    "clone()",
    "to_dense",
    "collect()",
    "format!",
    "Box::new",
    "available_parallelism",
];

/// Rules that `// audit: allow(rule) reason` may silence.
pub const ALLOW_RULES: &[&str] = &["alloc", "unsafe", "determinism", "simd"];

/// The only modules allowed to contain SIMD vector code (`std::arch` /
/// `core::arch` intrinsics, `#[target_feature]`): the `SparseKernel`
/// dispatch layer. Everything else reaches vector units through it, so
/// scalar fallbacks and feature detection live in exactly one place.
pub const SIMD_MODULES: &[&str] = &["rust/src/sparse/simd.rs"];

/// Run every rule over the scanned files; returns sorted findings.
pub fn run_all(files: &[SourceFile], config: &AuditConfig) -> Vec<Finding> {
    let mut findings = Vec::new();
    for sf in files {
        alloc_rule(sf, &mut findings);
        unsafe_rule(sf, config, &mut findings);
        determinism_rule(sf, config, &mut findings);
        simd_rule(sf, &mut findings);
        directive_rule(sf, &mut findings);
    }
    coverage_rule(files, config, &mut findings);
    serde_rule(files, config, &mut findings);
    super::report::sort_findings(&mut findings);
    findings
}

/// An `allow(rule)` directive on the finding's line or the line above it.
fn allowed(sf: &SourceFile, rule: &str, line: usize) -> bool {
    sf.directives.iter().any(|d| match d {
        Directive::Allow { line: al, rule: r, .. } => r == rule && (*al == line || *al + 1 == line),
        _ => false,
    })
}

/// Suffix match against an allowlist (entries are repo-relative paths).
fn allowlisted(path: &str, entries: &[AllowEntry]) -> bool {
    entries.iter().any(|e| {
        path == e.suffix || path.ends_with(&format!("/{}", e.suffix))
    })
}

fn alloc_rule(sf: &SourceFile, findings: &mut Vec<Finding>) {
    if sf.hot_regions.is_empty() {
        return;
    }
    for token in BANNED_HOT {
        for off in sf.find_token(token) {
            let Some(region) =
                sf.hot_regions.iter().find(|r| off >= r.start && off < r.end)
            else {
                continue;
            };
            let line = sf.line_of(off);
            if allowed(sf, "alloc", line) {
                continue;
            }
            findings.push(Finding::new(
                &sf.path,
                line,
                "alloc",
                format!(
                    "`{token}` inside the hot-path region opened at line {}; \
                     the tracking step must stay allocation-free \
                     (amortized one-time growth may use \
                     `// audit: allow(alloc) <reason>`)",
                    region.directive_line
                ),
            ));
        }
    }
}

fn coverage_rule(files: &[SourceFile], config: &AuditConfig, findings: &mut Vec<Finding>) {
    for req in &config.required_hot {
        match files.iter().find(|f| &f.path == req) {
            None => findings.push(Finding::new(
                req,
                0,
                "coverage",
                "required hot-path file was not scanned (missing or renamed?)".to_string(),
            )),
            Some(sf) if sf.hot_regions.is_empty() && sf.unclosed_hot.is_empty() => {
                findings.push(Finding::new(
                    req,
                    0,
                    "coverage",
                    "no `// audit: hot-path` region found; the allocation lint \
                     has nothing to check in a file that is required to have \
                     annotated hot paths"
                        .to_string(),
                ))
            }
            Some(_) => {}
        }
    }
}

/// A SAFETY comment covers its own line and, walking upward through
/// contiguous comment-only lines, every line below it. A contiguous run of
/// unsafe-bearing lines shares one header (the runs in `coljac.rs` read a
/// row index and immediately use it on the next line).
fn safety_covered(sf: &SourceFile, line: usize, token_lines: &[usize]) -> bool {
    if sf.safety_lines.contains(&line) {
        return true;
    }
    let mut l = line;
    while l > 1 {
        l -= 1;
        if sf.is_comment_only(l) {
            if sf.safety_lines.contains(&l) {
                return true;
            }
        } else if !token_lines.contains(&l) {
            return false;
        }
    }
    false
}

fn unsafe_rule(sf: &SourceFile, config: &AuditConfig, findings: &mut Vec<Finding>) {
    let mut lines: Vec<usize> = sf.find_token("unsafe").iter().map(|&o| sf.line_of(o)).collect();
    lines.dedup();
    if lines.is_empty() {
        return;
    }
    let in_allowlist = allowlisted(&sf.path, &config.unsafe_allow);
    for &line in &lines {
        if allowed(sf, "unsafe", line) {
            continue;
        }
        if !in_allowlist {
            findings.push(Finding::new(
                &sf.path,
                line,
                "unsafe",
                "`unsafe` outside the allowlisted module set \
                 (rust/audit/unsafe.allow); prefer a safe formulation, or \
                 allowlist the file with a written reason"
                    .to_string(),
            ));
        } else if !safety_covered(sf, line, &lines) {
            findings.push(Finding::new(
                &sf.path,
                line,
                "unsafe",
                "missing `// SAFETY:` comment naming the aliasing/lifetime \
                 invariant this unsafe relies on"
                    .to_string(),
            ));
        }
    }
}

fn determinism_rule(sf: &SourceFile, config: &AuditConfig, findings: &mut Vec<Finding>) {
    if allowlisted(&sf.path, &config.determinism_allow) {
        return;
    }
    for token in ["HashMap", "HashSet"] {
        for off in sf.find_token(token) {
            let line = sf.line_of(off);
            if allowed(sf, "determinism", line) {
                continue;
            }
            findings.push(Finding::new(
                &sf.path,
                line,
                "determinism",
                format!(
                    "`{token}` has nondeterministic iteration order (randomized \
                     hasher); anything feeding gradient accumulation or reports \
                     must use a Vec/BTreeMap, or the file must be allowlisted in \
                     rust/audit/determinism.allow with a reason"
                ),
            ));
        }
    }
}

/// SIMD containment: `std::arch` / `core::arch` intrinsic paths and
/// `#[target_feature]` may appear only in [`SIMD_MODULES`], and a module
/// using `#[target_feature]` must also contain a runtime feature-detection
/// guard (`is_x86_feature_detected!` or, on arm,
/// `is_aarch64_feature_detected!`) — the static witness that every
/// feature-gated entry point (AVX2, AVX-512, NEON alike) sits behind
/// detection with a scalar fallback, never called bare. (A bare `arch`
/// identifier is ubiquitous — `Arch`, `arch_s` — so the rule matches the
/// unambiguous path/attribute spellings on the stripped code, not the
/// token.)
fn simd_rule(sf: &SourceFile, findings: &mut Vec<Finding>) {
    let mut hits: Vec<(usize, &str)> = Vec::new();
    for needle in ["std::arch", "core::arch"] {
        let mut from = 0usize;
        while let Some(rel) = sf.code[from..].find(needle) {
            let off = from + rel;
            hits.push((off, needle));
            from = off + needle.len();
        }
    }
    for off in sf.find_token("target_feature") {
        hits.push((off, "target_feature"));
    }
    if hits.is_empty() {
        return;
    }
    hits.sort();
    let in_simd_module = SIMD_MODULES
        .iter()
        .any(|m| &sf.path == m || sf.path.ends_with(&format!("/{m}")));
    let has_detection = !sf.find_token("is_x86_feature_detected").is_empty()
        || !sf.find_token("is_aarch64_feature_detected").is_empty();
    let mut flagged_lines: Vec<usize> = Vec::new();
    for (off, what) in hits {
        let line = sf.line_of(off);
        if flagged_lines.contains(&line) || allowed(sf, "simd", line) {
            continue;
        }
        if !in_simd_module {
            flagged_lines.push(line);
            findings.push(Finding::new(
                &sf.path,
                line,
                "simd",
                format!(
                    "`{what}` outside the SIMD kernel module set ({SIMD_MODULES:?}); \
                     route vector code through the `SparseKernel` dispatch layer \
                     instead of open-coding intrinsics"
                ),
            ));
        } else if what == "target_feature" && !has_detection {
            flagged_lines.push(line);
            findings.push(Finding::new(
                &sf.path,
                line,
                "simd",
                "`#[target_feature]` without any `is_x86_feature_detected!` / \
                 `is_aarch64_feature_detected!` guard in the module; \
                 feature-gated kernels must sit behind runtime detection with \
                 a scalar fallback"
                    .to_string(),
            ));
        }
    }
}

fn directive_rule(sf: &SourceFile, findings: &mut Vec<Finding>) {
    for d in &sf.directives {
        match d {
            Directive::Malformed { line, text } => findings.push(Finding::new(
                &sf.path,
                *line,
                "directive",
                format!(
                    "malformed `// audit:` directive `{text}` \
                     (expected `hot-path` or `allow(rule) reason`)"
                ),
            )),
            Directive::Allow { line, rule, .. } if !ALLOW_RULES.contains(&rule.as_str()) => {
                findings.push(Finding::new(
                    &sf.path,
                    *line,
                    "directive",
                    format!("unknown rule `{rule}` in allow(...); known: {ALLOW_RULES:?}"),
                ))
            }
            _ => {}
        }
    }
    for &line in &sf.unclosed_hot {
        findings.push(Finding::new(
            &sf.path,
            line,
            "directive",
            "`// audit: hot-path` is not followed by a brace-matched block".to_string(),
        ));
    }
}

// ---------------------------------------------------------------------------
// serde-format: structural fingerprint of the checkpoint blob layout
// ---------------------------------------------------------------------------

/// Committed pin: the blessed (version, fingerprint) pair.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SerdePin {
    pub version: u32,
    pub fingerprint: u64,
}

/// What the tree actually encodes right now.
#[derive(Clone, Debug)]
pub struct SerdeSnapshot {
    pub fingerprint: u64,
    pub version: u32,
    /// Where findings anchor: the `CHECKPOINT_VERSION` definition.
    pub anchor_path: String,
    pub anchor_line: usize,
}

fn is_ident(b: u8) -> bool {
    b == b'_' || b.is_ascii_alphanumeric()
}

/// Maximal identifier runs in stripped code (runs starting with a digit —
/// numeric literals — are skipped).
fn ident_tokens(code: &str) -> Vec<&str> {
    let b = code.as_bytes();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < b.len() {
        if is_ident(b[i]) {
            let start = i;
            while i < b.len() && is_ident(b[i]) {
                i += 1;
            }
            if !b[start].is_ascii_digit() {
                out.push(&code[start..i]);
            }
        } else {
            i += 1;
        }
    }
    out
}

fn is_serde_token(tok: &str) -> bool {
    tok.starts_with("put_")
        || tok.starts_with("get_")
        || tok == "encode_container"
        || tok == "decode_container"
        || tok == "expect_end"
}

/// Cut the stripped code at `#[cfg(test)] mod …` so tests don't perturb the
/// fingerprint.
fn truncate_at_test_mod(code: &str) -> &str {
    let needle = "#[cfg(test)]";
    let mut from = 0usize;
    while let Some(rel) = code[from..].find(needle) {
        let pos = from + rel;
        if code[pos + needle.len()..].trim_start().starts_with("mod ") {
            return &code[..pos];
        }
        from = pos + needle.len();
    }
    code
}

fn find_checkpoint_version(code: &str) -> Option<(u32, usize)> {
    let pos = code.find("const CHECKPOINT_VERSION")?;
    let rest = &code[pos..];
    let eq = rest.find('=')?;
    let tail = rest[eq + 1..].trim_start();
    let digits: String =
        tail.chars().take_while(|c| c.is_ascii_digit() || *c == '_').collect();
    let v: u32 = digits.replace('_', "").parse().ok()?;
    Some((v, pos))
}

/// Fingerprint the serde surface: the ordered stream of `put_*`/`get_*`/
/// container identifiers in `config.serde_files` (tests excluded), FNV-1a
/// hashed with `0xFF` separators and the file path + `0xFE` as a prefix per
/// file. Field reorderings, insertions and deletions all move the hash;
/// renames of unrelated locals do not.
pub fn serde_snapshot(
    files: &[SourceFile],
    config: &AuditConfig,
) -> Result<SerdeSnapshot, Finding> {
    let mut hasher = Fnv64::new();
    let mut version: Option<(u32, String, usize)> = None;
    for path in &config.serde_files {
        let sf = files.iter().find(|f| &f.path == path).ok_or_else(|| {
            Finding::new(
                path,
                0,
                "serde-format",
                "fingerprinted file was not scanned (missing or renamed?)".to_string(),
            )
        })?;
        let code = truncate_at_test_mod(&sf.code);
        hasher.write_bytes(sf.path.as_bytes());
        hasher.write_u8(0xFE);
        for tok in ident_tokens(code) {
            if is_serde_token(tok) {
                hasher.write_bytes(tok.as_bytes());
                hasher.write_u8(0xFF);
            }
        }
        if version.is_none() {
            if let Some((v, off)) = find_checkpoint_version(code) {
                version = Some((v, sf.path.clone(), sf.line_of(off)));
            }
        }
    }
    let anchor = config.serde_files.first().cloned().unwrap_or_default();
    let (version, anchor_path, anchor_line) = version.ok_or_else(|| {
        Finding::new(
            &anchor,
            0,
            "serde-format",
            "no `const CHECKPOINT_VERSION` definition found in the \
             fingerprinted files"
                .to_string(),
        )
    })?;
    Ok(SerdeSnapshot { fingerprint: hasher.finish(), version, anchor_path, anchor_line })
}

pub fn parse_pin(text: &str) -> Result<SerdePin, String> {
    let mut version: Option<u32> = None;
    let mut fingerprint: Option<u64> = None;
    for line in text.lines() {
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        if let Some(v) = t.strip_prefix("version ") {
            version =
                Some(v.trim().parse().map_err(|_| format!("bad version `{}`", v.trim()))?);
        } else if let Some(v) = t.strip_prefix("fingerprint ") {
            let h = v.trim().strip_prefix("0x").unwrap_or(v.trim());
            fingerprint = Some(
                u64::from_str_radix(h, 16).map_err(|_| format!("bad fingerprint `{h}`"))?,
            );
        } else {
            return Err(format!("unrecognized pin line `{t}`"));
        }
    }
    match (version, fingerprint) {
        (Some(version), Some(fingerprint)) => Ok(SerdePin { version, fingerprint }),
        _ => Err("pin must define both `version` and `fingerprint`".to_string()),
    }
}

pub fn render_pin(pin: &SerdePin) -> String {
    format!(
        "# Structural pin of the checkpoint blob layout (see rust/src/analysis/).\n\
         # If `repro audit` fails here, the serde field order changed: bump\n\
         # CHECKPOINT_VERSION in rust/src/train/checkpoint.rs, then refresh this\n\
         # file with `repro audit --repin-serde`.\n\
         version {}\n\
         fingerprint 0x{:016x}\n",
        pin.version, pin.fingerprint
    )
}

fn serde_rule(files: &[SourceFile], config: &AuditConfig, findings: &mut Vec<Finding>) {
    if config.serde_files.is_empty() {
        return;
    }
    let snap = match serde_snapshot(files, config) {
        Ok(s) => s,
        Err(f) => {
            findings.push(f);
            return;
        }
    };
    let Some(pin_path) = &config.pin_path else {
        return;
    };
    let text = match std::fs::read_to_string(pin_path) {
        Ok(t) => t,
        Err(_) => {
            findings.push(Finding::new(
                &snap.anchor_path,
                snap.anchor_line,
                "serde-format",
                format!(
                    "serde-format pin missing at {}; seed it with `repro audit \
                     --repin-serde` (computed fingerprint 0x{:016x})",
                    pin_path.display(),
                    snap.fingerprint
                ),
            ));
            return;
        }
    };
    let pin = match parse_pin(&text) {
        Ok(p) => p,
        Err(e) => {
            findings.push(Finding::new(
                &snap.anchor_path,
                snap.anchor_line,
                "serde-format",
                format!("corrupt serde-format pin at {}: {e}", pin_path.display()),
            ));
            return;
        }
    };
    match (pin.fingerprint == snap.fingerprint, pin.version == snap.version) {
        (true, true) => {}
        (false, true) => findings.push(Finding::new(
            &snap.anchor_path,
            snap.anchor_line,
            "serde-format",
            format!(
                "checkpoint blob layout changed without a version bump: \
                 computed fingerprint 0x{:016x} != pinned 0x{:016x} while \
                 CHECKPOINT_VERSION is still {}; bump it, then run \
                 `repro audit --repin-serde`",
                snap.fingerprint, pin.fingerprint, snap.version
            ),
        )),
        (false, false) => findings.push(Finding::new(
            &snap.anchor_path,
            snap.anchor_line,
            "serde-format",
            format!(
                "CHECKPOINT_VERSION is {} (pin has {}) and the layout \
                 fingerprint moved to 0x{:016x}; refresh the pin with \
                 `repro audit --repin-serde`",
                snap.version, pin.version, snap.fingerprint
            ),
        )),
        (true, false) => findings.push(Finding::new(
            &snap.anchor_path,
            snap.anchor_line,
            "serde-format",
            format!(
                "CHECKPOINT_VERSION is {} but the pin says {} although the \
                 layout fingerprint is unchanged; refresh the pin with \
                 `repro audit --repin-serde`",
                snap.version, pin.version
            ),
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn cfg() -> AuditConfig {
        AuditConfig {
            root: PathBuf::new(),
            src_dirs: Vec::new(),
            required_hot: Vec::new(),
            unsafe_allow: Vec::new(),
            determinism_allow: Vec::new(),
            serde_files: Vec::new(),
            pin_path: None,
        }
    }

    fn entry(suffix: &str) -> AllowEntry {
        AllowEntry { suffix: suffix.to_string(), reason: "test".to_string() }
    }

    #[test]
    fn alloc_rule_fires_inside_hot_regions_only() {
        let raw = "\
fn cold() {
    let v = vec![0.0f32; 8];
    drop(v);
}
// audit: hot-path
fn hot(n: usize) -> usize {
    let v = vec![0.0f32; n];
    v.len()
}
";
        let sf = SourceFile::parse("src/x.rs", raw);
        let f = run_all(std::slice::from_ref(&sf), &cfg());
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!((f[0].rule, f[0].line), ("alloc", 7));
    }

    #[test]
    fn allow_directive_silences_alloc_on_next_line() {
        let raw = "\
// audit: hot-path
fn hot(n: usize) -> usize {
    // audit: allow(alloc) amortized spare-pool refill
    let v = vec![0.0f32; n];
    v.len()
}
";
        let sf = SourceFile::parse("src/x.rs", raw);
        assert!(run_all(std::slice::from_ref(&sf), &cfg()).is_empty());
    }

    #[test]
    fn safety_header_covers_a_contiguous_unsafe_run() {
        let raw = "\
fn f(xs: &[u32], d: &[f32]) -> f32 {
    // SAFETY: indices come from the in-bounds row table.
    let i = unsafe { *xs.get_unchecked(0) } as usize;
    let v = unsafe { *d.get_unchecked(i) };
    v
}
";
        let sf = SourceFile::parse("src/sparse/coljac.rs", raw);
        let mut config = cfg();
        config.unsafe_allow.push(entry("src/sparse/coljac.rs"));
        assert!(run_all(std::slice::from_ref(&sf), &config).is_empty());
    }

    #[test]
    fn unsafe_without_safety_or_outside_allowlist_is_flagged() {
        let raw = "fn f(p: *const u32) -> u32 { unsafe { *p } }\n";
        let sf = SourceFile::parse("src/other.rs", raw);
        let f = run_all(std::slice::from_ref(&sf), &cfg());
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "unsafe");
        assert!(f[0].message.contains("allowlisted"), "{}", f[0].message);

        let mut config = cfg();
        config.unsafe_allow.push(entry("src/other.rs"));
        let f = run_all(std::slice::from_ref(&sf), &config);
        assert_eq!(f.len(), 1);
        assert!(f[0].message.contains("SAFETY"), "{}", f[0].message);
    }

    #[test]
    fn determinism_rule_and_its_allowlist() {
        let raw = "use std::collections::HashMap;\nfn f() -> HashMap<u8, u8> { HashMap::new() }\n";
        let sf = SourceFile::parse("src/h.rs", raw);
        let f = run_all(std::slice::from_ref(&sf), &cfg());
        assert_eq!(f.len(), 3, "{f:?}");
        assert!(f.iter().all(|x| x.rule == "determinism"));

        let mut config = cfg();
        config.determinism_allow.push(entry("src/h.rs"));
        assert!(run_all(std::slice::from_ref(&sf), &config).is_empty());
    }

    #[test]
    fn pin_round_trips_and_rejects_garbage() {
        let pin = SerdePin { version: 3, fingerprint: 0x0123_4567_89ab_cdef };
        let parsed = parse_pin(&render_pin(&pin)).unwrap();
        assert_eq!(parsed, pin);
        assert!(parse_pin("version 1\n").is_err());
        assert!(parse_pin("nonsense\n").is_err());
        assert!(parse_pin("version x\nfingerprint 0x0\n").is_err());
    }

    #[test]
    fn serde_snapshot_tracks_write_order_not_unrelated_code() {
        let serde_a = "\
pub const CHECKPOINT_VERSION: u32 = 1;
fn encode(w: &mut W) {
    w.put_u32(CHECKPOINT_VERSION);
    w.put_str(arch);
    w.put_f32s(theta);
}
#[cfg(test)]
mod tests {
    fn t() { w.put_u64(9); }
}
";
        // Same stream, different local names / formatting / test body.
        let serde_b = "\
pub const CHECKPOINT_VERSION: u32 = 1;
fn encode(out: &mut W) {
    out.put_u32(CHECKPOINT_VERSION);
    out.put_str(architecture);
    out.put_f32s(parameters);
}
#[cfg(test)]
mod tests {
    fn t() { w.put_bools(&[true]); }
}
";
        // Reordered fields: must move the fingerprint.
        let serde_c = serde_a.replace("put_str(arch);\n    w.put_f32s(theta);", "put_f32s(theta);\n    w.put_str(arch);");
        let mut config = cfg();
        config.serde_files.push("src/serde.rs".to_string());
        let snap = |raw: &str| {
            let sf = SourceFile::parse("src/serde.rs", raw);
            serde_snapshot(std::slice::from_ref(&sf), &config).unwrap()
        };
        let (a, b, c) = (snap(serde_a), snap(serde_b), snap(&serde_c));
        assert_eq!(a.fingerprint, b.fingerprint);
        assert_ne!(a.fingerprint, c.fingerprint);
        assert_eq!(a.version, 1);
        assert_eq!(a.anchor_line, 1);
    }

    #[test]
    fn simd_rule_confines_intrinsics_to_the_kernel_module() {
        // Intrinsics outside the kernel module: flagged.
        let raw = "use std::arch::x86_64::_mm256_setzero_ps;\nfn f() {}\n";
        let sf = SourceFile::parse("rust/src/grad/rtrl.rs", raw);
        let f = run_all(std::slice::from_ref(&sf), &cfg());
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "simd");
        assert!(f[0].message.contains("SparseKernel"), "{}", f[0].message);

        // Same code inside the kernel module with a detection guard: clean.
        let guarded = "\
use std::arch::x86_64::_mm256_setzero_ps;
fn have() -> bool { is_x86_feature_detected!(\"avx2\") }
#[target_feature(enable = \"avx2\")]
unsafe fn k() {}
";
        let sf = SourceFile::parse("rust/src/sparse/simd.rs", guarded);
        let f: Vec<_> = run_all(std::slice::from_ref(&sf), &cfg())
            .into_iter()
            .filter(|x| x.rule == "simd")
            .collect();
        assert!(f.is_empty(), "{f:?}");

        // target_feature without any runtime detection: flagged even inside
        // the module (no scalar-fallback witness).
        let unguarded = "#[target_feature(enable = \"avx2\")]\nunsafe fn k() {}\n";
        let sf = SourceFile::parse("rust/src/sparse/simd.rs", unguarded);
        let f: Vec<_> = run_all(std::slice::from_ref(&sf), &cfg())
            .into_iter()
            .filter(|x| x.rule == "simd")
            .collect();
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].message.contains("is_x86_feature_detected"), "{}", f[0].message);

        // AVX-512 and NEON spellings are covered by the same containment:
        // intrinsic paths outside the module are flagged whatever the width
        // or architecture.
        let avx512_out = "use std::arch::x86_64::_mm512_fmadd_ps;\nfn f() {}\n";
        let sf = SourceFile::parse("rust/src/grad/snap.rs", avx512_out);
        let f = run_all(std::slice::from_ref(&sf), &cfg());
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "simd");
        let neon_out = "use std::arch::aarch64::vfmaq_f32;\nfn f() {}\n";
        let sf = SourceFile::parse("rust/src/tensor/ops.rs", neon_out);
        let f = run_all(std::slice::from_ref(&sf), &cfg());
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "simd");

        // The aarch64 detection macro is an accepted witness for
        // target_feature inside the module (the NEON kernels guard with it).
        let neon_guarded = "\
use std::arch::aarch64::vfmaq_f32;
fn have() -> bool { std::arch::is_aarch64_feature_detected!(\"neon\") }
#[target_feature(enable = \"neon\")]
unsafe fn k() {}
";
        let sf = SourceFile::parse("rust/src/sparse/simd.rs", neon_guarded);
        let f: Vec<_> = run_all(std::slice::from_ref(&sf), &cfg())
            .into_iter()
            .filter(|x| x.rule == "simd")
            .collect();
        assert!(f.is_empty(), "{f:?}");

        // A mention in a comment or string must not trip the rule.
        let commented = "// std::arch is discussed here; \"target_feature\" too\nfn f() {}\n";
        let sf = SourceFile::parse("rust/src/grad/rtrl.rs", commented);
        assert!(run_all(std::slice::from_ref(&sf), &cfg()).is_empty());

        // The allow directive silences it with a written reason.
        let allowed = "\
// audit: allow(simd) one-off cpuid probe for the bench header
use std::arch::x86_64::__cpuid;
fn f() {}
";
        let sf = SourceFile::parse("rust/src/benchutil.rs", allowed);
        assert!(run_all(std::slice::from_ref(&sf), &cfg()).is_empty());
    }

    #[test]
    fn unknown_allow_rule_and_malformed_directives_are_findings() {
        let raw = "// audit: allow(speed) because\n// audit: nonsense\nfn f() {}\n";
        let sf = SourceFile::parse("src/d.rs", raw);
        let f = run_all(std::slice::from_ref(&sf), &cfg());
        assert_eq!(f.len(), 2, "{f:?}");
        assert!(f.iter().all(|x| x.rule == "directive"));
    }

    #[test]
    fn coverage_rule_requires_a_region() {
        let sf = SourceFile::parse("src/grad/bptt.rs", "fn step() { let x = 1; }\n");
        let mut config = cfg();
        config.required_hot.push("src/grad/bptt.rs".to_string());
        config.required_hot.push("src/grad/ghost.rs".to_string());
        let f = run_all(std::slice::from_ref(&sf), &config);
        assert_eq!(f.len(), 2, "{f:?}");
        assert!(f.iter().all(|x| x.rule == "coverage"));
    }
}
