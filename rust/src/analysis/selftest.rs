//! Seeded-violation self-tests: every audit rule must demonstrably *catch*
//! its violation, not just pass on a clean tree. Each case builds a tiny
//! fixture repo in a temp dir, plants one violation, runs the full audit
//! pipeline (scan → rules → sort), and asserts the exact findings; the
//! escape hatches (allow directives, allowlists, repin) are exercised too.
//!
//! Run via `repro audit --self-test` (the CI lint job does) or through the
//! unit-test wrapper below. A rule whose self-test fails is a rule that
//! cannot be trusted to block a regression.

use super::report::Finding;
use super::AuditConfig;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};

static COUNTER: AtomicUsize = AtomicUsize::new(0);

/// Temp-dir fixture repo; removed on drop (best effort).
struct Fixture {
    root: PathBuf,
}

impl Fixture {
    fn new(name: &str) -> Result<Fixture, String> {
        let root = std::env::temp_dir().join(format!(
            "snap-audit-selftest-{}-{}-{}",
            std::process::id(),
            COUNTER.fetch_add(1, Ordering::Relaxed),
            name
        ));
        std::fs::create_dir_all(root.join("src")).map_err(|e| format!("mkdir fixture: {e}"))?;
        Ok(Fixture { root })
    }

    fn write(&self, rel: &str, content: &str) -> Result<(), String> {
        let p = self.root.join(rel);
        if let Some(parent) = p.parent() {
            std::fs::create_dir_all(parent)
                .map_err(|e| format!("mkdir {}: {e}", parent.display()))?;
        }
        std::fs::write(&p, content).map_err(|e| format!("write {}: {e}", p.display()))
    }

    /// Minimal config over this fixture: scan `src/`, everything else off.
    fn config(&self) -> AuditConfig {
        AuditConfig {
            root: self.root.clone(),
            src_dirs: vec!["src".to_string()],
            required_hot: Vec::new(),
            unsafe_allow: Vec::new(),
            determinism_allow: Vec::new(),
            serde_files: Vec::new(),
            pin_path: None,
        }
    }

    fn audit(&self, config: &AuditConfig) -> Result<Vec<Finding>, String> {
        super::run_audit(config).map_err(|e| format!("audit failed to run: {e}"))
    }
}

impl Drop for Fixture {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.root);
    }
}

fn allow(suffix: &str) -> super::AllowEntry {
    super::AllowEntry { suffix: suffix.to_string(), reason: "selftest".to_string() }
}

/// Findings must equal `want` as (rule, line) pairs, in order.
fn expect(findings: &[Finding], want: &[(&str, usize)]) -> Result<(), String> {
    let got: Vec<(&str, usize)> = findings.iter().map(|f| (f.rule, f.line)).collect();
    if got == want {
        Ok(())
    } else {
        Err(format!("findings mismatch:\n  got  {got:?}\n  want {want:?}\n  full {findings:?}"))
    }
}

fn expect_one_containing(findings: &[Finding], needle: &str) -> Result<(), String> {
    if findings.len() == 1 && findings[0].message.contains(needle) {
        Ok(())
    } else {
        Err(format!("wanted one finding containing {needle:?}, got {findings:?}"))
    }
}

fn case_alloc_detected() -> Result<(), String> {
    let fx = Fixture::new("alloc")?;
    fx.write(
        "src/hot.rs",
        "// audit: hot-path\npub fn hot(n: usize) -> usize {\n    let v = vec![0.0f32; n];\n    v.len()\n}\n",
    )?;
    expect(&fx.audit(&fx.config())?, &[("alloc", 3)])
}

fn case_alloc_allow_silences() -> Result<(), String> {
    let fx = Fixture::new("alloc-allow")?;
    fx.write(
        "src/hot.rs",
        "// audit: hot-path\npub fn hot(n: usize) -> usize {\n    // audit: allow(alloc) amortized one-time growth\n    let v = vec![0.0f32; n];\n    v.len()\n}\n",
    )?;
    expect(&fx.audit(&fx.config())?, &[])
}

fn case_coverage_requires_regions() -> Result<(), String> {
    let fx = Fixture::new("coverage")?;
    fx.write("src/cold.rs", "pub fn cold() -> usize {\n    7\n}\n")?;
    let mut config = fx.config();
    config.required_hot.push("src/cold.rs".to_string());
    config.required_hot.push("src/ghost.rs".to_string());
    expect(&fx.audit(&config)?, &[("coverage", 0), ("coverage", 0)])
}

fn case_unsafe_outside_allowlist() -> Result<(), String> {
    let fx = Fixture::new("unsafe-module")?;
    fx.write(
        "src/newmod.rs",
        "pub fn first(p: *const u32) -> u32 {\n    unsafe { *p }\n}\n",
    )?;
    let findings = fx.audit(&fx.config())?;
    expect(&findings, &[("unsafe", 2)])?;
    expect_one_containing(&findings, "allowlisted")
}

fn case_unsafe_requires_safety_comment() -> Result<(), String> {
    let fx = Fixture::new("unsafe-safety")?;
    fx.write(
        "src/newmod.rs",
        "pub fn first(p: *const u32) -> u32 {\n    unsafe { *p }\n}\n",
    )?;
    let mut config = fx.config();
    config.unsafe_allow.push(allow("src/newmod.rs"));
    let findings = fx.audit(&config)?;
    expect(&findings, &[("unsafe", 2)])?;
    expect_one_containing(&findings, "SAFETY")?;
    // Adding the SAFETY comment heals it.
    fx.write(
        "src/newmod.rs",
        "pub fn first(p: *const u32) -> u32 {\n    // SAFETY: caller guarantees p is valid for reads.\n    unsafe { *p }\n}\n",
    )?;
    expect(&fx.audit(&config)?, &[])
}

fn case_determinism() -> Result<(), String> {
    let fx = Fixture::new("determinism")?;
    fx.write(
        "src/table.rs",
        "use std::collections::HashMap;\npub fn t() -> usize {\n    HashMap::<u8, u8>::new().len()\n}\n",
    )?;
    expect(&fx.audit(&fx.config())?, &[("determinism", 1), ("determinism", 3)])?;
    let mut config = fx.config();
    config.determinism_allow.push(allow("src/table.rs"));
    expect(&fx.audit(&config)?, &[])
}

fn case_serde_format_guard() -> Result<(), String> {
    let fx = Fixture::new("serde")?;
    let serde = "pub struct W;\nimpl W {\n    pub fn put_u32(&mut self, _v: u32) {}\n    pub fn put_str(&mut self, _s: &str) {}\n    pub fn put_f32s(&mut self, _xs: &[f32]) {}\n}\n";
    let layout_v1 = "pub const CHECKPOINT_VERSION: u32 = 1;\npub fn encode(w: &mut crate::serde::W) {\n    w.put_u32(CHECKPOINT_VERSION);\n    w.put_str(\"arch\");\n    w.put_f32s(&[1.0]);\n}\n";
    // Same version, put_str/put_f32s swapped: a silent layout change.
    let layout_v1_swapped = "pub const CHECKPOINT_VERSION: u32 = 1;\npub fn encode(w: &mut crate::serde::W) {\n    w.put_u32(CHECKPOINT_VERSION);\n    w.put_f32s(&[1.0]);\n    w.put_str(\"arch\");\n}\n";
    let layout_v2_swapped = layout_v1_swapped.replace("u32 = 1;", "u32 = 2;");
    fx.write("src/serde.rs", serde)?;
    fx.write("src/checkpoint.rs", layout_v1)?;
    let mut config = fx.config();
    config.serde_files.push("src/serde.rs".to_string());
    config.serde_files.push("src/checkpoint.rs".to_string());
    config.pin_path = Some(fx.root.join("audit/serde_format.pin"));

    // No pin yet: the audit demands one.
    expect_one_containing(&fx.audit(&config)?, "--repin-serde")?;
    super::repin_serde(&config).map_err(|e| format!("repin: {e}"))?;
    expect(&fx.audit(&config)?, &[])?;

    // Layout change without a version bump: the core violation.
    fx.write("src/checkpoint.rs", layout_v1_swapped)?;
    let findings = fx.audit(&config)?;
    expect(&findings, &[("serde-format", 1)])?;
    expect_one_containing(&findings, "without a version bump")?;

    // Bumping the version makes the fix explicit: refresh the pin.
    fx.write("src/checkpoint.rs", &layout_v2_swapped)?;
    expect_one_containing(&fx.audit(&config)?, "--repin-serde")?;
    super::repin_serde(&config).map_err(|e| format!("repin: {e}"))?;
    expect(&fx.audit(&config)?, &[])
}

fn case_simd_containment() -> Result<(), String> {
    let fx = Fixture::new("simd")?;
    // Intrinsics planted outside the kernel module: must be caught.
    fx.write(
        "src/grad/fast.rs",
        "use std::arch::x86_64::_mm256_setzero_ps;\npub fn f() {}\n",
    )?;
    let findings = fx.audit(&fx.config())?;
    expect(&findings, &[("simd", 1)])?;
    expect_one_containing(&findings, "SparseKernel")?;
    // The containment covers every vector ISA, not just AVX2: seeded
    // AVX-512 and NEON intrinsic paths outside the module are violations
    // too.
    fx.write(
        "src/grad/fast.rs",
        "use std::arch::x86_64::_mm512_fmadd_ps;\npub fn f() {}\n",
    )?;
    expect(&fx.audit(&fx.config())?, &[("simd", 1)])?;
    fx.write(
        "src/grad/fast.rs",
        "use std::arch::aarch64::vfmaq_f32;\npub fn f() {}\n",
    )?;
    expect(&fx.audit(&fx.config())?, &[("simd", 1)])?;
    // Moving them into the kernel module without a detection guard is still
    // a violation (no scalar-fallback witness)…
    fx.write("src/grad/fast.rs", "pub fn f() {}\n")?;
    fx.write(
        "rust/src/sparse/simd.rs",
        "#[target_feature(enable = \"avx2\")]\npub unsafe fn k() {}\n",
    )?;
    let mut config = fx.config();
    config.src_dirs.push("rust/src".to_string());
    config.unsafe_allow.push(allow("rust/src/sparse/simd.rs"));
    let findings: Vec<Finding> = fx
        .audit(&config)?
        .into_iter()
        .filter(|f| f.rule == "simd")
        .collect();
    expect(&findings, &[("simd", 1)])?;
    expect_one_containing(&findings, "is_x86_feature_detected")?;
    // …and adding the runtime guard heals it.
    fx.write(
        "rust/src/sparse/simd.rs",
        "pub fn have() -> bool {\n    is_x86_feature_detected!(\"avx2\")\n}\n#[target_feature(enable = \"avx2\")]\npub unsafe fn k() {}\n",
    )?;
    let findings: Vec<Finding> = fx
        .audit(&config)?
        .into_iter()
        .filter(|f| f.rule == "simd")
        .collect();
    expect(&findings, &[])?;
    // The aarch64 detection macro is an equally valid witness — NEON
    // kernels guarded with it are clean.
    fx.write(
        "rust/src/sparse/simd.rs",
        "pub fn have() -> bool {\n    std::arch::is_aarch64_feature_detected!(\"neon\")\n}\n#[target_feature(enable = \"neon\")]\npub unsafe fn k() {}\n",
    )?;
    let findings: Vec<Finding> = fx
        .audit(&config)?
        .into_iter()
        .filter(|f| f.rule == "simd")
        .collect();
    expect(&findings, &[])
}

fn case_malformed_directives() -> Result<(), String> {
    let fx = Fixture::new("directive")?;
    fx.write(
        "src/bad.rs",
        "// audit: hotpath\npub const X: usize = 1;\n// audit: hot-path\npub const Y: usize = 2;\n",
    )?;
    expect(&fx.audit(&fx.config())?, &[("directive", 1), ("directive", 3)])
}

type Case = (&'static str, fn() -> Result<(), String>);

const CASES: &[Case] = &[
    ("alloc-detects-seeded-violation", case_alloc_detected),
    ("alloc-allow-directive-silences", case_alloc_allow_silences),
    ("coverage-requires-hot-regions", case_coverage_requires_regions),
    ("unsafe-outside-allowlist", case_unsafe_outside_allowlist),
    ("unsafe-requires-safety-comment", case_unsafe_requires_safety_comment),
    ("determinism-hashmap", case_determinism),
    ("serde-format-guard", case_serde_format_guard),
    ("simd-containment", case_simd_containment),
    ("malformed-directives", case_malformed_directives),
];

/// Run every self-test case; `Err` (nonzero exit) if any rule failed to
/// catch its seeded violation.
pub fn run_selftests() -> crate::errors::Result<()> {
    let mut failed = 0usize;
    for (name, case) in CASES {
        match case() {
            Ok(()) => println!("audit self-test {name}: ok"),
            Err(e) => {
                failed += 1;
                println!("audit self-test {name}: FAILED\n  {e}");
            }
        }
    }
    crate::ensure!(failed == 0, "audit self-test: {failed} of {} case(s) failed", CASES.len());
    println!("audit self-test: all {} case(s) passed", CASES.len());
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn every_seeded_violation_is_caught() {
        super::run_selftests().unwrap();
    }
}
