//! Findings: the audit's output type and its text / JSON renderings.
//!
//! Text findings print as `path:line: [rule] message` — the shape compilers
//! and editors already know how to jump on. The JSON rendering is
//! hand-serialized (zero-dependency crate) and shape-stable:
//!
//! ```json
//! {"count":1,"findings":[{"path":"…","line":12,"rule":"alloc","message":"…"}]}
//! ```

/// One audit finding, anchored to a source span.
#[derive(Clone, Debug, PartialEq)]
pub struct Finding {
    /// Repo-relative path (forward slashes).
    pub path: String,
    /// 1-based line (0 for whole-file findings, e.g. a missing file).
    pub line: usize,
    /// Stable rule id: `alloc`, `coverage`, `unsafe`, `determinism`,
    /// `serde-format`, `directive`.
    pub rule: &'static str,
    pub message: String,
}

impl Finding {
    pub fn new(path: &str, line: usize, rule: &'static str, message: String) -> Finding {
        Finding { path: path.to_string(), line, rule, message }
    }
}

/// Deterministic report order: by path, then line, then rule.
pub fn sort_findings(findings: &mut [Finding]) {
    findings.sort_by(|a, b| {
        (a.path.as_str(), a.line, a.rule).cmp(&(b.path.as_str(), b.line, b.rule))
    });
}

/// `path:line: [rule] message`, one finding per line.
pub fn render_text(findings: &[Finding]) -> String {
    let mut out = String::new();
    for f in findings {
        out.push_str(&format!("{}:{}: [{}] {}\n", f.path, f.line, f.rule, f.message));
    }
    out
}

/// Machine-readable report (single line).
pub fn render_json(findings: &[Finding]) -> String {
    let mut out = String::from("{\"count\":");
    out.push_str(&findings.len().to_string());
    out.push_str(",\"findings\":[");
    for (i, f) in findings.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str("{\"path\":\"");
        out.push_str(&json_escape(&f.path));
        out.push_str("\",\"line\":");
        out.push_str(&f.line.to_string());
        out.push_str(",\"rule\":\"");
        out.push_str(&json_escape(f.rule));
        out.push_str("\",\"message\":\"");
        out.push_str(&json_escape(&f.message));
        out.push_str("\"}");
    }
    out.push_str("]}");
    out
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sort_is_by_path_then_line_then_rule() {
        let mut fs = vec![
            Finding::new("b.rs", 1, "alloc", "x".into()),
            Finding::new("a.rs", 9, "alloc", "x".into()),
            Finding::new("a.rs", 2, "determinism", "x".into()),
            Finding::new("a.rs", 2, "alloc", "x".into()),
        ];
        sort_findings(&mut fs);
        let order: Vec<(&str, usize, &str)> =
            fs.iter().map(|f| (f.path.as_str(), f.line, f.rule)).collect();
        assert_eq!(
            order,
            vec![("a.rs", 2, "alloc"), ("a.rs", 2, "determinism"), ("a.rs", 9, "alloc"), ("b.rs", 1, "alloc")]
        );
    }

    #[test]
    fn text_rendering_is_compiler_shaped() {
        let fs = vec![Finding::new("src/x.rs", 12, "alloc", "`vec!` in a hot region".into())];
        assert_eq!(render_text(&fs), "src/x.rs:12: [alloc] `vec!` in a hot region\n");
    }

    #[test]
    fn json_rendering_escapes_and_counts() {
        let fs = vec![Finding::new("a\"b.rs", 3, "unsafe", "tab\there".into())];
        let j = render_json(&fs);
        assert_eq!(
            j,
            "{\"count\":1,\"findings\":[{\"path\":\"a\\\"b.rs\",\"line\":3,\
             \"rule\":\"unsafe\",\"message\":\"tab\\there\"}]}"
        );
        assert_eq!(render_json(&[]), "{\"count\":0,\"findings\":[]}");
    }
}
