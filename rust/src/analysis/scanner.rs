//! Lexer-light Rust source scanner: the substrate every audit rule runs on.
//!
//! A full Rust parser is overkill (and unavailable — the crate registry is
//! offline), but raw substring matching is unsound: `unsafe` in a doc
//! comment or `vec!` in an error-message string must not trip a rule. The
//! middle ground implemented here is a character-level state machine that
//! produces a *stripped* view of the source — comments and string/char
//! literal contents replaced by spaces, byte-for-byte, newlines preserved —
//! so that:
//!
//! * byte offsets and line numbers in the stripped view equal those in the
//!   raw file (findings report real `file:line` spans), and
//! * token searches over the stripped view only ever match real code.
//!
//! The scanner understands line comments, nested block comments, string
//! literals (with escapes), byte strings, raw (byte) strings with any hash
//! depth, and char literals vs. lifetimes (`'a'` is blanked, `'a` is kept).
//!
//! While stripping, it also collects the comment stream and parses the
//! `// audit:` directive grammar out of it (see [`Directive`]), resolves
//! `hot-path` directives to brace-matched byte ranges ([`HotRegion`]), and
//! records which lines carry a `SAFETY:` comment — everything the rules in
//! [`super::rules`] consume.

/// A parsed `// audit:` directive.
///
/// Grammar (line comments only; doc comments are ignored):
///
/// ```text
/// // audit: hot-path              — the next `{…}` block is a hot region
/// // audit: allow(RULE) REASON    — silence RULE findings on this line and
///                                   the next; REASON is mandatory
/// ```
///
/// Anything else after `// audit:` is [`Directive::Malformed`] — itself
/// reported as a finding, so a typo can never silently disable a rule.
#[derive(Clone, Debug, PartialEq)]
pub enum Directive {
    /// `// audit: hot-path`
    HotPath { line: usize },
    /// `// audit: allow(rule) reason`
    Allow { line: usize, rule: String, reason: String },
    /// Unparseable `// audit:` comment, reported as a finding.
    Malformed { line: usize, text: String },
}

impl Directive {
    pub fn line(&self) -> usize {
        match self {
            Directive::HotPath { line } => *line,
            Directive::Allow { line, .. } => *line,
            Directive::Malformed { line, .. } => *line,
        }
    }
}

/// A `// audit: hot-path` region: the first brace-delimited block that
/// opens after the directive line, matched on the stripped view.
#[derive(Clone, Copy, Debug)]
pub struct HotRegion {
    pub directive_line: usize,
    /// Byte offset of the opening `{` in [`SourceFile::code`].
    pub start: usize,
    /// Byte offset one past the matching `}`.
    pub end: usize,
}

/// One scanned source file.
#[derive(Clone, Debug)]
pub struct SourceFile {
    /// Repo-relative path with forward slashes (stable across platforms).
    pub path: String,
    /// Stripped view: same length and line structure as the raw file, with
    /// comments and literal contents blanked.
    pub code: String,
    pub directives: Vec<Directive>,
    pub hot_regions: Vec<HotRegion>,
    /// `hot-path` directives with no following brace-matched block.
    pub unclosed_hot: Vec<usize>,
    /// Lines (1-based) whose comment text contains `SAFETY:`.
    pub safety_lines: Vec<usize>,
    /// Per line (index 0 = line 1): the line holds a comment but no code.
    comment_only: Vec<bool>,
    /// Byte offset of each line start in `code`.
    line_starts: Vec<usize>,
}

fn is_ident_byte(b: u8) -> bool {
    b == b'_' || b.is_ascii_alphanumeric()
}

fn prev_is_ident(bytes: &[u8], i: usize) -> bool {
    i > 0 && is_ident_byte(bytes[i - 1])
}

/// If `bytes[i..]` starts a raw (byte) string introducer — `r`, `br`, any
/// number of `#`, then `"` — return (offset of the opening quote, hashes).
fn raw_string_at(bytes: &[u8], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if bytes.get(j) == Some(&b'b') {
        j += 1;
    }
    if bytes.get(j) != Some(&b'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0usize;
    while bytes.get(j) == Some(&b'#') {
        hashes += 1;
        j += 1;
    }
    if bytes.get(j) == Some(&b'"') {
        Some((j, hashes))
    } else {
        None
    }
}

impl SourceFile {
    /// Scan one file. `path` is stored verbatim in every finding.
    pub fn parse(path: &str, raw: &str) -> SourceFile {
        let bytes = raw.as_bytes();
        let n = bytes.len();
        let mut code = bytes.to_vec();
        // (byte offset, raw text) of every comment, in file order.
        let mut comments: Vec<(usize, String)> = Vec::new();

        let mut i = 0usize;
        while i < n {
            let b = bytes[i];
            if b == b'/' && i + 1 < n && bytes[i + 1] == b'/' {
                let start = i;
                while i < n && bytes[i] != b'\n' {
                    code[i] = b' ';
                    i += 1;
                }
                comments.push((start, raw[start..i].to_string()));
            } else if b == b'/' && i + 1 < n && bytes[i + 1] == b'*' {
                let start = i;
                let mut depth = 1usize;
                code[i] = b' ';
                code[i + 1] = b' ';
                i += 2;
                while i < n && depth > 0 {
                    if bytes[i] == b'/' && i + 1 < n && bytes[i + 1] == b'*' {
                        depth += 1;
                        code[i] = b' ';
                        code[i + 1] = b' ';
                        i += 2;
                    } else if bytes[i] == b'*' && i + 1 < n && bytes[i + 1] == b'/' {
                        depth -= 1;
                        code[i] = b' ';
                        code[i + 1] = b' ';
                        i += 2;
                    } else {
                        if bytes[i] != b'\n' {
                            code[i] = b' ';
                        }
                        i += 1;
                    }
                }
                comments.push((start, raw[start..i].to_string()));
            } else if (b == b'r' || b == b'b') && !prev_is_ident(bytes, i) {
                if let Some((q, hashes)) = raw_string_at(bytes, i) {
                    // Raw (byte) string: blank everything between the quotes.
                    let mut j = q + 1;
                    while j < n {
                        if bytes[j] == b'"' {
                            let mut k = 0usize;
                            while k < hashes && bytes.get(j + 1 + k) == Some(&b'#') {
                                k += 1;
                            }
                            if k == hashes {
                                j += 1 + hashes;
                                break;
                            }
                        }
                        if bytes[j] != b'\n' {
                            code[j] = b' ';
                        }
                        j += 1;
                    }
                    i = j;
                } else if b == b'b' && i + 1 < n && bytes[i + 1] == b'"' {
                    i += 1; // byte string: let the `"` arm below handle it
                } else {
                    i += 1;
                }
            } else if b == b'"' {
                i += 1;
                while i < n {
                    if bytes[i] == b'\\' && i + 1 < n {
                        code[i] = b' ';
                        if bytes[i + 1] != b'\n' {
                            code[i + 1] = b' ';
                        }
                        i += 2;
                    } else if bytes[i] == b'"' {
                        i += 1;
                        break;
                    } else {
                        if bytes[i] != b'\n' {
                            code[i] = b' ';
                        }
                        i += 1;
                    }
                }
            } else if b == b'\'' {
                if i + 1 < n && bytes[i + 1] == b'\\' {
                    // Escaped char literal: blank to the closing quote.
                    i += 1;
                    while i < n && bytes[i] != b'\'' {
                        if bytes[i] == b'\\' && i + 1 < n {
                            code[i] = b' ';
                            if bytes[i + 1] != b'\n' {
                                code[i + 1] = b' ';
                            }
                            i += 2;
                        } else {
                            if bytes[i] != b'\n' {
                                code[i] = b' ';
                            }
                            i += 1;
                        }
                    }
                    if i < n {
                        i += 1; // closing quote
                    }
                } else if i + 2 < n && bytes[i + 2] == b'\'' && bytes[i + 1] != b'\'' {
                    // Simple one-byte char literal 'x' (covers '{', '"', …).
                    code[i + 1] = b' ';
                    i += 3;
                } else {
                    i += 1; // lifetime
                }
            } else {
                i += 1;
            }
        }

        let code = String::from_utf8(code).expect("stripping preserves UTF-8");

        let mut line_starts = vec![0usize];
        for (off, b) in code.bytes().enumerate() {
            if b == b'\n' {
                line_starts.push(off + 1);
            }
        }

        let comment_only: Vec<bool> = code
            .lines()
            .zip(raw.lines())
            .map(|(c, r)| c.trim().is_empty() && !r.trim().is_empty())
            .collect();

        let mut sf = SourceFile {
            path: path.to_string(),
            code,
            directives: Vec::new(),
            hot_regions: Vec::new(),
            unclosed_hot: Vec::new(),
            safety_lines: Vec::new(),
            comment_only,
            line_starts,
        };

        for (off, text) in &comments {
            let line = sf.line_of(*off);
            for (k, seg) in text.split('\n').enumerate() {
                if seg.contains("SAFETY:") {
                    sf.safety_lines.push(line + k);
                }
            }
            if let Some(d) = parse_directive(line, text) {
                sf.directives.push(d);
            }
        }

        // Resolve hot-path directives to brace-matched regions.
        let dirs = sf.directives.clone();
        for d in &dirs {
            if let Directive::HotPath { line } = d {
                match sf.match_block_after_line(*line) {
                    Some((start, end)) => {
                        sf.hot_regions.push(HotRegion { directive_line: *line, start, end })
                    }
                    None => sf.unclosed_hot.push(*line),
                }
            }
        }
        sf
    }

    /// 1-based line number of a byte offset.
    pub fn line_of(&self, offset: usize) -> usize {
        match self.line_starts.binary_search(&offset) {
            Ok(idx) => idx + 1,
            Err(idx) => idx, // idx >= 1 since line_starts[0] == 0
        }
    }

    pub fn line_count(&self) -> usize {
        self.comment_only.len()
    }

    /// The line holds a comment but no code.
    pub fn is_comment_only(&self, line: usize) -> bool {
        line >= 1 && self.comment_only.get(line - 1).copied().unwrap_or(false)
    }

    /// Find the first `{…}` block opening at or after the start of
    /// `line + 1`, brace-matched on the stripped view.
    fn match_block_after_line(&self, line: usize) -> Option<(usize, usize)> {
        let from = *self.line_starts.get(line)?; // start of the next line
        let bytes = self.code.as_bytes();
        let open = (from..bytes.len()).find(|&j| bytes[j] == b'{')?;
        let mut depth = 0usize;
        for j in open..bytes.len() {
            match bytes[j] {
                b'{' => depth += 1,
                b'}' => {
                    depth -= 1;
                    if depth == 0 {
                        return Some((open, j + 1));
                    }
                }
                _ => {}
            }
        }
        None
    }

    /// Byte offsets of identifier-boundary-respecting occurrences of
    /// `token` in the stripped view.
    pub fn find_token(&self, token: &str) -> Vec<usize> {
        let mut out = Vec::new();
        let bytes = self.code.as_bytes();
        let tlen = token.len();
        if tlen == 0 {
            return out;
        }
        let first_ident = is_ident_byte(token.as_bytes()[0]);
        let last_ident = is_ident_byte(token.as_bytes()[tlen - 1]);
        let mut from = 0usize;
        while let Some(rel) = self.code[from..].find(token) {
            let pos = from + rel;
            let pre_ok = !first_ident || !prev_is_ident(bytes, pos);
            let post_ok = !last_ident
                || pos + tlen >= bytes.len()
                || !is_ident_byte(bytes[pos + tlen]);
            if pre_ok && post_ok {
                out.push(pos);
            }
            from = pos + 1;
        }
        out
    }
}

fn parse_directive(line: usize, text: &str) -> Option<Directive> {
    // Only plain line comments carry directives (`///` and `//!` do not).
    let body = text.strip_prefix("//")?;
    if body.starts_with('/') || body.starts_with('!') {
        return None;
    }
    let body = body.trim_start();
    let rest = body.strip_prefix("audit:")?.trim();
    if rest == "hot-path" {
        return Some(Directive::HotPath { line });
    }
    if let Some(inner) = rest.strip_prefix("allow(") {
        if let Some(close) = inner.find(')') {
            let rule = inner[..close].trim().to_string();
            let reason = inner[close + 1..].trim().to_string();
            if !rule.is_empty() && !reason.is_empty() {
                return Some(Directive::Allow { line, rule, reason });
            }
        }
    }
    Some(Directive::Malformed { line, text: rest.to_string() })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stripping_blanks_comments_and_literals_but_keeps_structure() {
        let raw = "let a = \"vec![x]\"; // vec! in comment\nlet b = vec![0; 3];\n";
        let sf = SourceFile::parse("t.rs", raw);
        assert_eq!(sf.code.len(), raw.len());
        assert_eq!(sf.find_token("vec!").len(), 1);
        assert_eq!(sf.line_of(sf.find_token("vec!")[0]), 2);
    }

    #[test]
    fn char_literals_are_blanked_lifetimes_are_kept() {
        let raw = "fn f<'a>(x: &'a str) -> char { if x.is_empty() { '{' } else { '\\n' } }\n";
        let sf = SourceFile::parse("t.rs", raw);
        // The '{' char literal must not unbalance brace matching.
        assert!(sf.code.contains("'a str"));
        assert!(!sf.code.contains("'{'"));
    }

    #[test]
    fn raw_strings_are_blanked() {
        let raw = "let s = r#\"HashMap \"quoted\" inside\"#; let t = 1;\n";
        let sf = SourceFile::parse("t.rs", raw);
        assert!(sf.find_token("HashMap").is_empty());
        assert!(sf.code.contains("let t = 1;"));
    }

    #[test]
    fn nested_block_comments() {
        let raw = "/* outer /* inner */ still comment vec! */ let x = 1;\n";
        let sf = SourceFile::parse("t.rs", raw);
        assert!(sf.find_token("vec!").is_empty());
        assert!(sf.code.contains("let x = 1;"));
    }

    #[test]
    fn hot_path_directive_marks_the_next_block() {
        let raw = "\
// audit: hot-path
fn step(x: usize) -> usize {
    let y = x + 1;
    y
}
fn other() { let v = 2; }
";
        let sf = SourceFile::parse("t.rs", raw);
        assert_eq!(sf.hot_regions.len(), 1);
        let r = sf.hot_regions[0];
        assert_eq!(r.directive_line, 1);
        assert_eq!(sf.line_of(r.start), 2);
        assert_eq!(sf.line_of(r.end - 1), 5);
    }

    #[test]
    fn unclosed_hot_path_is_recorded() {
        let raw = "// audit: hot-path\nlet x = 1;\n";
        let sf = SourceFile::parse("t.rs", raw);
        assert!(sf.hot_regions.is_empty());
        assert_eq!(sf.unclosed_hot, vec![1]);
    }

    #[test]
    fn allow_directive_parses_rule_and_reason() {
        let raw = "// audit: allow(alloc) amortized spare-pool refill\nlet v = 1;\n";
        let sf = SourceFile::parse("t.rs", raw);
        assert_eq!(
            sf.directives,
            vec![Directive::Allow {
                line: 1,
                rule: "alloc".into(),
                reason: "amortized spare-pool refill".into(),
            }]
        );
    }

    #[test]
    fn malformed_directives_are_flagged_not_ignored() {
        for bad in ["// audit: hotpath", "// audit: allow(alloc)", "// audit: allow() x"] {
            let sf = SourceFile::parse("t.rs", &format!("{bad}\n"));
            assert!(
                matches!(sf.directives[0], Directive::Malformed { .. }),
                "{bad} should be malformed"
            );
        }
        // Doc comments never carry directives.
        let sf = SourceFile::parse("t.rs", "/// audit: hot-path\nfn f() {}\n");
        assert!(sf.directives.is_empty());
    }

    #[test]
    fn safety_lines_cover_line_and_block_comments() {
        let raw = "\
// SAFETY: slot t is in bounds.
let a = 1;
/* spans
   SAFETY: second line of a block */
let b = 2; // SAFETY: trailing
";
        let sf = SourceFile::parse("t.rs", raw);
        assert_eq!(sf.safety_lines, vec![1, 4, 5]);
        assert!(sf.is_comment_only(1));
        assert!(!sf.is_comment_only(2));
        assert!(sf.is_comment_only(3));
    }

    #[test]
    fn find_token_respects_identifier_boundaries() {
        let raw = "deny(unsafe_op_in_unsafe_fn); to_vec_scratch(); x.to_vec();\n";
        let sf = SourceFile::parse("t.rs", raw);
        assert!(sf.find_token("unsafe").is_empty());
        assert_eq!(sf.find_token("to_vec").len(), 1);
    }
}
