//! `repro audit` — zero-dependency static analysis of this repo's own
//! source, enforcing the invariants PRs 1–5 bought dynamically:
//!
//! * the tracking step is **allocation-free** (`alloc` + `coverage` rules
//!   over `// audit: hot-path` regions),
//! * `unsafe` stays rare, allowlisted and documented (`unsafe` rule),
//! * nothing order-nondeterministic feeds gradients or reports
//!   (`determinism` rule),
//! * SIMD intrinsics and `#[target_feature]` stay confined to the
//!   `SparseKernel` dispatch module, behind runtime feature detection with
//!   a scalar fallback (`simd` rule),
//! * the checkpoint blob layout cannot change silently (`serde-format`
//!   rule: a structural fingerprint of the serde field write-order, pinned
//!   in `rust/audit/serde_format.pin`, must move together with
//!   `CHECKPOINT_VERSION`).
//!
//! SnAp's premise (paper §3) is that Jacobian *structure* is static and
//! known ahead of time; this module applies the same bet to the codebase —
//! what is statically known (where hot loops are, where unsafe lives, what
//! the blob layout is) is statically checked, on every CI run, instead of
//! waiting for a bench gate or a corrupt checkpoint to notice.
//!
//! Layout: [`scanner`] turns each file into a stripped token-searchable
//! view (comments/strings blanked so they can't trip rules), [`rules`]
//! implements the rule set over it, [`report`] renders `file:line` findings
//! as text or JSON, [`selftest`] seeds one violation per rule in a
//! temp-dir fixture tree and asserts the audit catches it
//! (`repro audit --self-test`, run by the CI lint job).
//!
//! See the `audit` entry in `repro help` for the CLI surface and
//! `rust/audit/` for the allowlists and the serde pin.

pub mod report;
pub mod rules;
pub mod scanner;
pub mod selftest;

use crate::coordinator::Args;
use crate::errors::{Context, Error, Result};
use report::Finding;
use scanner::SourceFile;
use std::path::{Path, PathBuf};

/// One allowlist entry: a repo-relative path suffix plus the written reason
/// it is exempt (the reason is for humans; the audit only checks presence).
#[derive(Clone, Debug)]
pub struct AllowEntry {
    pub suffix: String,
    pub reason: String,
}

/// Everything an audit run is parameterized on. [`AuditConfig::for_repo`]
/// builds the shipped-tree configuration; the self-tests build fixture
/// configurations pointing at temp dirs.
#[derive(Clone, Debug)]
pub struct AuditConfig {
    /// Repo root; all paths below are relative to it.
    pub root: PathBuf,
    /// Directories scanned for `.rs` files (recursive, sorted).
    pub src_dirs: Vec<String>,
    /// Files that must contain at least one `// audit: hot-path` region —
    /// deleting the annotations is itself a finding.
    pub required_hot: Vec<String>,
    pub unsafe_allow: Vec<AllowEntry>,
    pub determinism_allow: Vec<AllowEntry>,
    /// Files whose serde token stream is fingerprinted, in fixed order.
    pub serde_files: Vec<String>,
    /// Committed (version, fingerprint) pin; `None` disables the check.
    pub pin_path: Option<PathBuf>,
}

/// Files that must keep their hot-path annotations: the tracking step in
/// every gradient algorithm, the sparse kernels under it, each cell's
/// forward/Jacobian refresh, the readout backward, and the per-lane session
/// step the serve runtime drives every tick.
const REQUIRED_HOT: &[&str] = &[
    "rust/src/cells/gru.rs",
    "rust/src/cells/lstm.rs",
    "rust/src/cells/vanilla.rs",
    "rust/src/grad/bptt.rs",
    "rust/src/grad/rflo.rs",
    "rust/src/grad/rtrl.rs",
    "rust/src/grad/snap.rs",
    "rust/src/grad/snap_topk.rs",
    "rust/src/grad/uoro.rs",
    "rust/src/models/readout.rs",
    "rust/src/sparse/coljac.rs",
    "rust/src/sparse/dynjac.rs",
    "rust/src/sparse/simd.rs",
    "rust/src/tensor/ops.rs",
    "rust/src/train/stepper.rs",
];

impl AuditConfig {
    /// The shipped-tree configuration, anchored at the repo root.
    pub fn for_repo(root: &Path) -> AuditConfig {
        AuditConfig {
            root: root.to_path_buf(),
            src_dirs: vec!["rust/src".to_string()],
            required_hot: REQUIRED_HOT.iter().map(|s| s.to_string()).collect(),
            unsafe_allow: load_allowlist(&root.join("rust/audit/unsafe.allow")),
            determinism_allow: load_allowlist(&root.join("rust/audit/determinism.allow")),
            serde_files: vec![
                "rust/src/runtime/serde.rs".to_string(),
                "rust/src/train/checkpoint.rs".to_string(),
                "rust/src/shard/protocol.rs".to_string(),
            ],
            pin_path: Some(root.join("rust/audit/serde_format.pin")),
        }
    }
}

/// Allowlist file format: `#` comments, blank lines, else
/// `<repo-relative-path> <reason…>`. A missing file is an empty list.
fn load_allowlist(path: &Path) -> Vec<AllowEntry> {
    let Ok(text) = std::fs::read_to_string(path) else {
        return Vec::new();
    };
    let mut out = Vec::new();
    for line in text.lines() {
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let (suffix, reason) = match t.split_once(char::is_whitespace) {
            Some((s, r)) => (s.to_string(), r.trim().to_string()),
            None => (t.to_string(), String::new()),
        };
        out.push(AllowEntry { suffix, reason });
    }
    out
}

fn walk_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
        .with_context(|| format!("audit: reading {}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk_rs(&p, out)?;
        } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(p);
        }
    }
    Ok(())
}

/// Scan every configured source dir into stripped [`SourceFile`]s, in a
/// deterministic (sorted) order with repo-relative forward-slash paths.
pub fn scan(config: &AuditConfig) -> Result<Vec<SourceFile>> {
    let mut files = Vec::new();
    for dir in &config.src_dirs {
        let d = config.root.join(dir);
        crate::ensure!(d.is_dir(), "audit: source dir {} not found", d.display());
        let mut paths = Vec::new();
        walk_rs(&d, &mut paths)?;
        for p in paths {
            let raw = std::fs::read_to_string(&p)
                .with_context(|| format!("audit: reading {}", p.display()))?;
            let rel = p.strip_prefix(&config.root).unwrap_or(&p);
            let rel = rel.to_string_lossy().replace('\\', "/");
            files.push(SourceFile::parse(&rel, &raw));
        }
    }
    Ok(files)
}

/// Scan + all rules; findings come back sorted by (path, line, rule).
pub fn run_audit(config: &AuditConfig) -> Result<Vec<Finding>> {
    let files = scan(config)?;
    Ok(rules::run_all(&files, config))
}

/// Recompute the serde fingerprint from the tree and (re)write the pin.
pub fn repin_serde(config: &AuditConfig) -> Result<rules::SerdePin> {
    let files = scan(config)?;
    let snap = rules::serde_snapshot(&files, config)
        .map_err(|f| Error::msg(format!("{}:{}: {}", f.path, f.line, f.message)))?;
    let pin = rules::SerdePin { version: snap.version, fingerprint: snap.fingerprint };
    let path = config
        .pin_path
        .as_ref()
        .context("audit: no serde pin path configured")?;
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)
            .with_context(|| format!("audit: creating {}", parent.display()))?;
    }
    std::fs::write(path, rules::render_pin(&pin))
        .with_context(|| format!("audit: writing {}", path.display()))?;
    Ok(pin)
}

/// Walk up from the current directory to the first ancestor containing
/// `rust/src/lib.rs` (the repo root).
fn discover_root() -> Result<PathBuf> {
    let mut dir = std::env::current_dir().context("audit: getting current dir")?;
    loop {
        if dir.join("rust/src/lib.rs").is_file() {
            return Ok(dir);
        }
        if !dir.pop() {
            crate::bail!(
                "audit: could not find the repo root (rust/src/lib.rs) above \
                 the current directory; pass --root"
            );
        }
    }
}

/// `repro audit [--root PATH] [--json] [--self-test] [--repin-serde]` —
/// exits nonzero (via `Err`) when any finding survives.
pub fn run_audit_cli(args: &Args) -> Result<()> {
    if args.bool_or("self-test", false) {
        return selftest::run_selftests();
    }
    let root = match args.get("root") {
        Some(r) => PathBuf::from(r),
        None => discover_root()?,
    };
    let config = AuditConfig::for_repo(&root);
    if args.bool_or("repin-serde", false) {
        let pin = repin_serde(&config)?;
        println!(
            "audit: pinned serde format: version {} fingerprint 0x{:016x}",
            pin.version, pin.fingerprint
        );
        return Ok(());
    }
    let files = scan(&config)?;
    let findings = rules::run_all(&files, &config);
    if args.bool_or("json", false) {
        println!("{}", report::render_json(&findings));
    } else if findings.is_empty() {
        println!("audit: clean ({} files scanned)", files.len());
    } else {
        print!("{}", report::render_text(&findings));
    }
    crate::ensure!(findings.is_empty(), "repro audit: {} finding(s)", findings.len());
    Ok(())
}
