//! SnAp — the Sparse n-Step Approximation (paper §3), the main contribution.
//!
//! Keeps only the influence-matrix entries that become nonzero within `n`
//! steps of the recurrent core: `P_n = pat(I) ∪ pat(D)·P_{n-1}`. The tracked
//! Jacobian lives in a fixed column-compressed layout ([`ColJacobian`]) and
//! the per-step update restricts `D_t·J_{t-1}` to that pattern.
//!
//! * SnAp-1 is effectively diagonal (one kept row per column for
//!   Vanilla/GRU) and costs no more than backprop (§3.1).
//! * SnAp-n for sparse nets is strictly less biased and strictly more
//!   expensive as n grows (§3.3); once `P_n` saturates it *is* sparse RTRL.

use crate::cells::Cell;
use crate::errors::Result;
use crate::grad::{check_state_tag, state_tags, GradAlgo};
use crate::runtime::serde::{Reader, Writer};
use crate::sparse::coljac::ColJacobian;
use crate::sparse::dynjac::DynJacobian;
use crate::sparse::immediate::ImmediateJac;
use crate::sparse::pattern::{snap_pattern, Pattern};

pub struct Snap<'c> {
    cell: &'c dyn Cell,
    n: usize,
    s: Vec<f32>,
    j: ColJacobian,
    d: DynJacobian,
    i_jac: ImmediateJac,
    cache: crate::cells::Cache,
    pattern_nnz: usize,
    /// persistent scratch (never serialized): next-state and padded-dlds
    s_next: Vec<f32>,
    dlds: Vec<f32>,
    last_flops: u64,
}

impl<'c> Snap<'c> {
    pub fn new(cell: &'c dyn Cell, n: usize) -> Self {
        assert!(n >= 1, "SnAp order must be >= 1");
        let i_jac = cell.immediate_structure();
        let pattern = snap_pattern(&cell.dynamics_pattern(), &i_jac.pattern(), n);
        Self::with_pattern(cell, n, &pattern)
    }

    /// Build with an explicit influence pattern (used by analyses that study
    /// pattern choices, e.g. Table 4's kept-mass accounting).
    pub fn with_pattern(cell: &'c dyn Cell, n: usize, pattern: &Pattern) -> Self {
        let ss = cell.state_size();
        Snap {
            cell,
            n,
            s: vec![0.0; ss],
            j: ColJacobian::from_pattern(pattern),
            d: cell.make_dyn_jacobian(),
            i_jac: cell.immediate_structure(),
            cache: cell.make_cache(),
            pattern_nnz: pattern.nnz(),
            s_next: vec![0.0; ss],
            dlds: vec![0.0; ss],
            last_flops: 0,
        }
    }

    pub fn order(&self) -> usize {
        self.n
    }

    /// Sparsity of the tracked Jacobian (Table 3's "SnAp-n J Sparsity" rows).
    pub fn jacobian_sparsity(&self) -> f64 {
        1.0 - self.j.density()
    }

    /// Read-only view of the approximate influence (Figure 6 analysis).
    pub fn influence(&self) -> &ColJacobian {
        &self.j
    }

    /// Tag the dynamics Jacobian's [`SparseKernel`](crate::sparse::SparseKernel)
    /// implementation (construction-time choice — see `SparsityPlan::kernel`).
    /// The [`ColJacobian`] update reads the tag off `d`, so one call covers
    /// both the refresh and the pattern-restricted product.
    pub fn set_kernel(&mut self, kernel: crate::sparse::simd::KernelKind) {
        self.d.set_kernel(kernel);
    }
}

impl GradAlgo for Snap<'_> {
    fn name(&self) -> String {
        format!("snap-{}", self.n)
    }

    fn reset(&mut self) {
        self.s.iter_mut().for_each(|v| *v = 0.0);
        self.j.reset();
    }

    // audit: hot-path
    fn step(&mut self, theta: &[f32], x: &[f32]) {
        // Allocation-free: forward into the owned scratch, then swap.
        self.cell.forward(theta, &self.s, x, &mut self.cache, &mut self.s_next);
        std::mem::swap(&mut self.s, &mut self.s_next);
        self.cell.dynamics(theta, &self.cache, &mut self.d);
        self.cell.immediate(&self.cache, &mut self.i_jac);
        self.j.update(&self.d, &self.i_jac);
        // O(1): the product term is cached in the ColJacobian (fixed pattern).
        self.last_flops = self.j.update_flops(self.i_jac.nnz());
    }

    fn hidden(&self) -> &[f32] {
        &self.s[..self.cell.hidden_size()]
    }

    fn state(&self) -> &[f32] {
        &self.s
    }

    // audit: hot-path
    fn inject_loss(&mut self, dl_dh: &[f32], g: &mut [f32]) {
        debug_assert_eq!(dl_dh.len(), self.cell.hidden_size());
        let ss = self.cell.state_size();
        if dl_dh.len() == ss {
            self.j.accumulate_grad(dl_dh, g);
        } else {
            // LSTM: pad [dl_dh ; 0] in the owned scratch (tail stays zero).
            self.dlds[..dl_dh.len()].copy_from_slice(dl_dh);
            self.j.accumulate_grad(&self.dlds, g);
        }
        self.last_flops += 2 * self.pattern_nnz as u64;
    }

    fn flush(&mut self, _theta: &[f32], _g: &mut [f32]) {}

    fn tracking_flops_per_step(&self) -> u64 {
        self.last_flops
    }

    fn tracking_memory_floats(&self) -> usize {
        self.j.nnz()
    }

    fn set_two_pass_update(&mut self, two_pass: bool) {
        self.j.set_two_pass(two_pass);
    }

    fn save_state(&self, w: &mut Writer) {
        w.put_u8(state_tags::SNAP);
        w.put_u64(self.n as u64);
        // The pattern is rebuilt from the cell on restore; the fingerprint
        // proves the rebuilt CSC layout indexes the same (row, col) slots.
        w.put_u64(self.j.structure_fingerprint());
        w.put_f32s(&self.s);
        w.put_f32s(self.j.vals());
    }

    fn load_state(&mut self, r: &mut Reader<'_>) -> Result<()> {
        check_state_tag(r.get_u8()?, state_tags::SNAP, &self.name())?;
        let n = r.get_u64()? as usize;
        crate::ensure!(
            n == self.n,
            "SnAp order mismatch: checkpoint snap-{n} vs run snap-{}",
            self.n
        );
        let fp = r.get_u64()?;
        let here = self.j.structure_fingerprint();
        crate::ensure!(
            fp == here,
            "SnAp influence-pattern fingerprint mismatch \
             (checkpoint {fp:#018x} vs rebuilt {here:#018x}): \
             the cell's sparsity pattern differs from the checkpointed run"
        );
        let s = r.get_f32s()?;
        crate::ensure!(
            s.len() == self.s.len(),
            "SnAp state length mismatch: checkpoint {} vs run {}",
            s.len(),
            self.s.len()
        );
        let vals = r.get_f32s()?;
        crate::ensure!(
            vals.len() == self.j.nnz(),
            "SnAp influence nnz mismatch: checkpoint {} vs run {}",
            vals.len(),
            self.j.nnz()
        );
        self.s = s;
        self.j.vals_mut().copy_from_slice(&vals);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::Arch;
    use crate::grad::rtrl::Rtrl;
    use crate::sparse::pattern::saturation_order;
    use crate::tensor::rng::Pcg32;

    fn run_both(
        arch: Arch,
        density: f64,
        n: usize,
        steps: usize,
        seed: u64,
    ) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Pcg32::seeded(seed);
        let (k, input) = (6, 3);
        let cell = arch.build(k, input, density, &mut rng);
        let theta = cell.init_params(&mut rng);
        let xs: Vec<Vec<f32>> =
            (0..steps).map(|_| (0..input).map(|_| rng.normal()).collect()).collect();
        let cs: Vec<Vec<f32>> =
            (0..steps).map(|_| (0..cell.hidden_size()).map(|_| rng.normal()).collect()).collect();

        let mut snap = Snap::new(cell.as_ref(), n);
        let mut g_snap = vec![0.0f32; cell.num_params()];
        let mut rtrl = Rtrl::new(cell.as_ref(), false);
        let mut g_rtrl = vec![0.0f32; cell.num_params()];
        for t in 0..steps {
            snap.step(&theta, &xs[t]);
            snap.inject_loss(&cs[t], &mut g_snap);
            rtrl.step(&theta, &xs[t]);
            rtrl.inject_loss(&cs[t], &mut g_rtrl);
        }
        (g_snap, g_rtrl)
    }

    #[test]
    fn snap_at_saturation_equals_rtrl() {
        // Paper §1: "SnAp becomes equivalent to RTRL when n is large."
        for arch in [Arch::Vanilla, Arch::Gru, Arch::Lstm] {
            let mut rng = Pcg32::seeded(700);
            let cell = arch.build(6, 3, 0.35, &mut rng);
            let sat = saturation_order(
                &cell.dynamics_pattern(),
                &cell.immediate_structure().pattern(),
                64,
            );
            let (g_snap, g_rtrl) = run_both(arch, 0.35, sat, 6, 700);
            let scale = g_rtrl.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-6);
            for (a, b) in g_snap.iter().zip(g_rtrl.iter()) {
                assert!((a - b).abs() / scale < 1e-4, "{arch:?} sat={sat}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn snap2_on_dense_gru_equals_rtrl() {
        // §3.1: "for dense networks SnAp-2 already reduces to full RTRL."
        let (g_snap, g_rtrl) = run_both(Arch::Gru, 1.0, 2, 5, 701);
        let scale = g_rtrl.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-6);
        for (a, b) in g_snap.iter().zip(g_rtrl.iter()) {
            assert!((a - b).abs() / scale < 1e-4);
        }
    }

    #[test]
    fn bias_decreases_with_n() {
        // SnAp-n is strictly less biased as n increases (§3.3): compare
        // cosine distance to the exact RTRL gradient.
        let mut dist = Vec::new();
        for n in 1..=3 {
            let (g_snap, g_rtrl) = run_both(Arch::Gru, 0.25, n, 8, 702);
            let dot: f32 = g_snap.iter().zip(&g_rtrl).map(|(a, b)| a * b).sum();
            let na: f32 = g_snap.iter().map(|a| a * a).sum::<f32>().sqrt();
            let nb: f32 = g_rtrl.iter().map(|b| b * b).sum::<f32>().sqrt();
            dist.push(1.0 - dot / (na * nb).max(1e-12));
        }
        assert!(
            dist[0] >= dist[1] - 1e-5 && dist[1] >= dist[2] - 1e-5,
            "cosine distance should shrink with n: {dist:?}"
        );
        assert!(dist[2] < 0.05, "snap-3 should be close to exact: {dist:?}");
    }

    #[test]
    fn snap1_pattern_nnz_equals_params_for_gru() {
        let mut rng = Pcg32::seeded(703);
        let cell = Arch::Gru.build(8, 4, 0.5, &mut rng);
        let snap = Snap::new(cell.as_ref(), 1);
        // One kept row per column (Engel GRU) → nnz == p.
        assert_eq!(snap.influence().nnz(), cell.num_params());
    }

    #[test]
    fn jacobian_sparsity_decreases_with_n() {
        let mut rng = Pcg32::seeded(704);
        let cell = Arch::Gru.build(12, 4, 0.25, &mut rng);
        let s1 = Snap::new(cell.as_ref(), 1).jacobian_sparsity();
        let s2 = Snap::new(cell.as_ref(), 2).jacobian_sparsity();
        let s3 = Snap::new(cell.as_ref(), 3).jacobian_sparsity();
        assert!(s1 > s2 && s2 > s3, "{s1} {s2} {s3}");
    }

    #[test]
    fn stale_jacobian_persists_across_updates() {
        // §2.2: after a weight update the influence is NOT reset.
        let mut rng = Pcg32::seeded(705);
        let cell = Arch::Gru.build(5, 2, 1.0, &mut rng);
        let mut theta = cell.init_params(&mut rng);
        let mut snap = Snap::new(cell.as_ref(), 1);
        snap.step(&theta, &[0.5, -0.5]);
        let norm_before: f32 =
            snap.influence().to_dense().norm();
        // simulate an optimizer update
        for v in theta.iter_mut() {
            *v += 0.01;
        }
        snap.step(&theta, &[0.1, 0.2]);
        let norm_after: f32 = snap.influence().to_dense().norm();
        assert!(norm_before > 0.0 && norm_after > 0.0);
    }
}
