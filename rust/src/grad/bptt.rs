//! Backpropagation Through Time (paper §2, eq. 1).
//!
//! Stores one forward cache + one loss cotangent per step of the current
//! window; `flush` runs the reverse sweep
//! `ds_{t-1} = D_tᵀ·ds_t`, `gθ += I_tᵀ·ds_t` and clears the window. Under
//! the sparse-D contract the `D_tᵀ·ds_t` step is a sparse `matvec_t` over a
//! [`DynJacobian`] — O(nnz(D)) per step, so sparse-network BPTT pays the
//! paper's `d·(k² + p)` line, not `k² + p`. All sweep buffers are owned by
//! the instance: no per-step or per-flush allocation.
//! With `flush` called every step this is truncated BPTT with T=1 (the
//! fully-online regime of §5.2 where BPTT "completely fails to learn
//! long-term structure"); with one flush per sequence it is full BPTT.

use crate::cells::{backward_step, Cache, Cell};
use crate::errors::Result;
use crate::grad::{check_state_tag, state_tags, GradAlgo};
use crate::runtime::serde::{Reader, Writer};
use crate::sparse::dynjac::DynJacobian;
use crate::sparse::immediate::ImmediateJac;

pub struct Bptt<'c> {
    cell: &'c dyn Cell,
    /// current state
    s: Vec<f32>,
    /// per-step: state *before* the step (needed to re-enter the window)
    caches: Vec<Cache>,
    dl_dh: Vec<Vec<f32>>,
    /// scratch (never serialized): sparse D, forward next-state, and the
    /// two backward-sweep cotangent buffers
    d: DynJacobian,
    i_jac: ImmediateJac,
    spare_caches: Vec<Cache>,
    /// recycled per-step cotangent buffers (like `spare_caches`)
    spare_dl: Vec<Vec<f32>>,
    s_next: Vec<f32>,
    ds: Vec<f32>,
    ds_prev: Vec<f32>,
    last_flops: u64,
}

impl<'c> Bptt<'c> {
    pub fn new(cell: &'c dyn Cell) -> Self {
        let ss = cell.state_size();
        Bptt {
            cell,
            s: vec![0.0; ss],
            caches: Vec::new(),
            dl_dh: Vec::new(),
            d: cell.make_dyn_jacobian(),
            i_jac: cell.immediate_structure(),
            spare_caches: Vec::new(),
            spare_dl: Vec::new(),
            s_next: vec![0.0; ss],
            ds: vec![0.0; ss],
            ds_prev: vec![0.0; ss],
            last_flops: 0,
        }
    }

    /// Number of steps currently buffered.
    pub fn window_len(&self) -> usize {
        self.caches.len()
    }

    /// Tag the dynamics Jacobian's [`SparseKernel`](crate::sparse::SparseKernel)
    /// implementation (construction-time choice — see `SparsityPlan::kernel`).
    pub fn set_kernel(&mut self, kernel: crate::sparse::simd::KernelKind) {
        self.d.set_kernel(kernel);
    }
}

impl GradAlgo for Bptt<'_> {
    fn name(&self) -> String {
        "bptt".into()
    }

    fn reset(&mut self) {
        self.s.iter_mut().for_each(|v| *v = 0.0);
        self.spare_caches.append(&mut self.caches);
        self.spare_dl.append(&mut self.dl_dh);
    }

    // audit: hot-path
    fn step(&mut self, theta: &[f32], x: &[f32]) {
        let mut cache = self.spare_caches.pop().unwrap_or_else(|| self.cell.make_cache());
        self.cell.forward(theta, &self.s, x, &mut cache, &mut self.s_next);
        std::mem::swap(&mut self.s, &mut self.s_next);
        self.caches.push(cache);
        let mut dl = self
            .spare_dl
            .pop()
            // audit: allow(alloc) cold spare-pool refill, amortized to zero
            .unwrap_or_else(|| vec![0.0; self.cell.hidden_size()]);
        dl.iter_mut().for_each(|v| *v = 0.0);
        self.dl_dh.push(dl);
        self.last_flops = 0;
    }

    fn hidden(&self) -> &[f32] {
        &self.s[..self.cell.hidden_size()]
    }

    fn state(&self) -> &[f32] {
        &self.s
    }

    fn inject_loss(&mut self, dl_dh: &[f32], _g: &mut [f32]) {
        let last = self.dl_dh.last_mut().expect("inject_loss before step");
        for (a, b) in last.iter_mut().zip(dl_dh) {
            *a += b;
        }
    }

    // audit: hot-path
    fn flush(&mut self, theta: &[f32], g: &mut [f32]) {
        let hs = self.cell.hidden_size();
        self.ds.iter_mut().for_each(|v| *v = 0.0);
        let mut flops = 0u64;
        for t in (0..self.caches.len()).rev() {
            // add this step's direct loss cotangent (hidden part of the state)
            for (i, &v) in self.dl_dh[t].iter().enumerate() {
                self.ds[i] += v;
            }
            self.cell.dynamics(theta, &self.caches[t], &mut self.d);
            self.cell.immediate(&self.caches[t], &mut self.i_jac);
            // ds_prev = Dᵀ·ds (sparse, overwrites the scratch), gθ += Iᵀ·ds.
            backward_step(&self.d, &self.i_jac, &self.ds, &mut self.ds_prev, g);
            std::mem::swap(&mut self.ds, &mut self.ds_prev);
            flops += 2 * self.d.nnz() as u64 + 2 * self.i_jac.nnz() as u64 + hs as u64;
        }
        self.last_flops = flops;
        self.spare_caches.append(&mut self.caches);
        self.spare_dl.append(&mut self.dl_dh);
    }

    fn tracking_flops_per_step(&self) -> u64 {
        // amortized: backward cost of one step — sparse Dᵀds (2·nnz(D), the
        // Sparse-BPTT `d·k²` term of Table 1) + Iᵀds (p).
        2 * self.d.nnz() as u64 + 2 * self.i_jac.nnz() as u64
    }

    fn tracking_memory_floats(&self) -> usize {
        // window of caches (T·k-style storage)
        let per_cache: usize = self
            .caches
            .first()
            .map(|c| c.bufs.iter().map(|b| b.len()).sum())
            .unwrap_or(0);
        self.caches.len() * per_cache + self.dl_dh.iter().map(|v| v.len()).sum::<usize>()
    }

    /// **Window-boundary-only resume policy**: BPTT's deferred window (the
    /// per-step forward caches and loss cotangents) is deliberately not
    /// serialized — the training drivers only checkpoint at update
    /// boundaries, where `flush` has just drained the window, so the window
    /// length recorded here is always 0 in practice. A checkpoint taken
    /// mid-window (window length > 0) records that fact and `load_state`
    /// refuses it with a named error rather than resuming with silently
    /// truncated credit assignment.
    fn save_state(&self, w: &mut Writer) {
        w.put_u8(state_tags::BPTT);
        w.put_u64(self.caches.len() as u64);
        w.put_f32s(&self.s);
    }

    fn load_state(&mut self, r: &mut Reader<'_>) -> Result<()> {
        check_state_tag(r.get_u8()?, state_tags::BPTT, "bptt")?;
        let window = r.get_u64()?;
        crate::ensure!(
            window == 0,
            "BPTT checkpoint was taken mid-window ({window} buffered steps); \
             BPTT is only resumable at flushed update boundaries"
        );
        let s = r.get_f32s()?;
        crate::ensure!(
            s.len() == self.s.len(),
            "BPTT state length mismatch: checkpoint {} vs run {}",
            s.len(),
            self.s.len()
        );
        // Start from an empty window, matching the saved boundary.
        self.spare_caches.append(&mut self.caches);
        self.spare_dl.append(&mut self.dl_dh);
        self.s = s;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::Arch;
    use crate::tensor::rng::Pcg32;

    /// Finite-difference check of the full BPTT gradient on a toy loss
    /// L = Σ_t c_tᵀ h_t over a short sequence.
    fn bptt_fd_check(arch: Arch, density: f64) {
        let mut rng = Pcg32::seeded(500);
        let k = 5;
        let input = 3;
        let steps = 4;
        let cell = arch.build(k, input, density, &mut rng);
        let mut theta = cell.init_params(&mut rng);
        let xs: Vec<Vec<f32>> =
            (0..steps).map(|_| (0..input).map(|_| rng.normal()).collect()).collect();
        let cs: Vec<Vec<f32>> =
            (0..steps).map(|_| (0..cell.hidden_size()).map(|_| rng.normal()).collect()).collect();

        let loss = |theta: &[f32]| -> f32 {
            let mut cache = cell.make_cache();
            let mut s = vec![0.0; cell.state_size()];
            let mut s2 = vec![0.0; cell.state_size()];
            let mut total = 0.0f32;
            for t in 0..steps {
                cell.forward(theta, &s, &xs[t], &mut cache, &mut s2);
                std::mem::swap(&mut s, &mut s2);
                total += s[..cell.hidden_size()]
                    .iter()
                    .zip(&cs[t])
                    .map(|(h, c)| h * c)
                    .sum::<f32>();
            }
            total
        };

        let mut algo = Bptt::new(cell.as_ref());
        let mut g = vec![0.0f32; cell.num_params()];
        algo.reset();
        for t in 0..steps {
            algo.step(&theta, &xs[t]);
            algo.inject_loss(&cs[t], &mut g);
        }
        algo.flush(&theta, &mut g);

        let eps = 1e-2f32;
        let mut checked = 0;
        for j in (0..cell.num_params()).step_by((cell.num_params() / 25).max(1)) {
            let orig = theta[j];
            theta[j] = orig + eps;
            let lp = loss(&theta);
            theta[j] = orig - eps;
            let lm = loss(&theta);
            theta[j] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - g[j]).abs() < 5e-2 * (1.0 + fd.abs()),
                "{arch:?} d={density} param {j}: fd={fd} an={}",
                g[j]
            );
            checked += 1;
        }
        assert!(checked >= 10);
    }

    #[test]
    fn gradient_matches_fd_vanilla() {
        bptt_fd_check(Arch::Vanilla, 1.0);
        bptt_fd_check(Arch::Vanilla, 0.4);
    }

    #[test]
    fn gradient_matches_fd_gru() {
        bptt_fd_check(Arch::Gru, 1.0);
        bptt_fd_check(Arch::Gru, 0.4);
    }

    #[test]
    fn gradient_matches_fd_lstm() {
        bptt_fd_check(Arch::Lstm, 1.0);
        bptt_fd_check(Arch::Lstm, 0.4);
    }

    #[test]
    fn flush_clears_window() {
        let mut rng = Pcg32::seeded(501);
        let cell = Arch::Gru.build(4, 2, 1.0, &mut rng);
        let theta = cell.init_params(&mut rng);
        let mut algo = Bptt::new(cell.as_ref());
        let mut g = vec![0.0; cell.num_params()];
        for _ in 0..3 {
            algo.step(&theta, &[0.1, -0.2]);
        }
        assert_eq!(algo.window_len(), 3);
        algo.flush(&theta, &mut g);
        assert_eq!(algo.window_len(), 0);
        // memory accounting reflects the cleared window
        assert_eq!(algo.tracking_memory_floats(), 0);
    }

    #[test]
    fn t1_flush_equals_single_step_grad() {
        // With T=1, flushing after each step only credits the immediate path.
        let mut rng = Pcg32::seeded(502);
        let cell = Arch::Vanilla.build(4, 2, 1.0, &mut rng);
        let theta = cell.init_params(&mut rng);
        let x = vec![0.3f32, -0.4];
        let c = vec![1.0f32, -1.0, 0.5, 2.0];

        let mut a1 = Bptt::new(cell.as_ref());
        let mut g1 = vec![0.0; cell.num_params()];
        a1.step(&theta, &x);
        a1.inject_loss(&c, &mut g1);
        a1.flush(&theta, &mut g1);

        // same as a window of 1 inside a longer run
        let mut a2 = Bptt::new(cell.as_ref());
        let mut g2 = vec![0.0; cell.num_params()];
        a2.step(&theta, &x);
        a2.inject_loss(&c, &mut g2);
        a2.flush(&theta, &mut g2);
        for (u, v) in g1.iter().zip(g2.iter()) {
            assert!((u - v).abs() < 1e-6);
        }
    }
}
