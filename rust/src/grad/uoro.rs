//! UORO — Unbiased Online Recurrent Optimization (Tallec & Ollivier 2018;
//! paper §1/§4's stochastic baseline).
//!
//! Maintains a rank-1 estimate `J_t ≈ ũ_t ṽ_tᵀ` that is unbiased over the
//! random sign vectors ν:
//!
//! ```text
//! ũ' = ρ0·(D·ũ) + ρ1·ν
//! ṽ' = ṽ/ρ0 + (Iᵀν)/ρ1
//! ```
//!
//! with the variance-minimizing scalars
//! `ρ0 = √(‖ṽ‖/‖D·ũ‖)`, `ρ1 = √(‖Iᵀν‖/‖ν‖)`.
//! Cost is `O(k² + p)` per step — same order as TBPTT — but the estimator's
//! noise is what the paper's Fig. 3 exposes.

use crate::cells::Cell;
use crate::errors::Result;
use crate::grad::{check_state_tag, state_tags, GradAlgo};
use crate::runtime::serde::{Reader, Writer};
use crate::sparse::dynjac::DynJacobian;
use crate::sparse::immediate::ImmediateJac;
use crate::tensor::ops::dot;
use crate::tensor::rng::Pcg32;

pub struct Uoro<'c> {
    cell: &'c dyn Cell,
    s: Vec<f32>,
    u: Vec<f32>,
    v: Vec<f32>,
    d: DynJacobian,
    i_jac: ImmediateJac,
    cache: crate::cells::Cache,
    rng: Pcg32,
    eps: f32,
    /// persistent scratch (never serialized): next-state, the ν sign draw,
    /// D·ũ, and Iᵀν
    s_next: Vec<f32>,
    nu: Vec<f32>,
    du: Vec<f32>,
    itnu: Vec<f32>,
    last_flops: u64,
}

impl<'c> Uoro<'c> {
    pub fn new(cell: &'c dyn Cell, rng: Pcg32) -> Self {
        let ss = cell.state_size();
        let p = cell.num_params();
        Uoro {
            cell,
            s: vec![0.0; ss],
            u: vec![0.0; ss],
            v: vec![0.0; p],
            d: cell.make_dyn_jacobian(),
            i_jac: cell.immediate_structure(),
            cache: cell.make_cache(),
            rng,
            eps: 1e-7,
            s_next: vec![0.0; ss],
            nu: vec![0.0; ss],
            du: vec![0.0; ss],
            itnu: vec![0.0; p],
            last_flops: 0,
        }
    }

    /// Current rank-1 factors (tests / diagnostics).
    pub fn factors(&self) -> (&[f32], &[f32]) {
        (&self.u, &self.v)
    }

    /// Tag the dynamics Jacobian's [`SparseKernel`](crate::sparse::SparseKernel)
    /// implementation (construction-time choice — see `SparsityPlan::kernel`).
    pub fn set_kernel(&mut self, kernel: crate::sparse::simd::KernelKind) {
        self.d.set_kernel(kernel);
    }
}

fn norm(xs: &[f32]) -> f32 {
    dot(xs, xs).sqrt()
}

impl GradAlgo for Uoro<'_> {
    fn name(&self) -> String {
        "uoro".into()
    }

    fn reset(&mut self) {
        self.s.iter_mut().for_each(|v| *v = 0.0);
        self.u.iter_mut().for_each(|v| *v = 0.0);
        self.v.iter_mut().for_each(|v| *v = 0.0);
    }

    // audit: hot-path
    fn step(&mut self, theta: &[f32], x: &[f32]) {
        let ss = self.cell.state_size();
        let p = self.cell.num_params();
        // Allocation-free: forward into the owned scratch, then swap.
        self.cell.forward(theta, &self.s, x, &mut self.cache, &mut self.s_next);
        std::mem::swap(&mut self.s, &mut self.s_next);
        self.cell.dynamics(theta, &self.cache, &mut self.d);
        self.cell.immediate(&self.cache, &mut self.i_jac);

        // ν ∈ {±1}^state
        for v in self.nu.iter_mut() {
            *v = self.rng.sign();
        }
        // D·ũ through the sparse dynamics Jacobian — O(nnz(D)).
        self.d.matvec_into(&self.u, &mut self.du);
        self.itnu.iter_mut().for_each(|v| *v = 0.0);
        self.i_jac.matvec_t_acc(&self.nu, &mut self.itnu);

        let rho0 = ((norm(&self.v) + self.eps) / (norm(&self.du) + self.eps)).sqrt();
        let rho1 = ((norm(&self.itnu) + self.eps) / (norm(&self.nu) + self.eps)).sqrt();

        for i in 0..ss {
            self.u[i] = rho0 * self.du[i] + rho1 * self.nu[i];
        }
        for j in 0..p {
            self.v[j] = self.v[j] / rho0 + self.itnu[j] / rho1;
        }
        self.last_flops =
            2 * self.d.nnz() as u64 + 2 * self.i_jac.nnz() as u64 + 4 * (ss + p) as u64;
    }

    fn hidden(&self) -> &[f32] {
        &self.s[..self.cell.hidden_size()]
    }

    fn state(&self) -> &[f32] {
        &self.s
    }

    // audit: hot-path
    fn inject_loss(&mut self, dl_dh: &[f32], g: &mut [f32]) {
        // g += (dl_ds·ũ)·ṽ
        let coef = dl_dh.iter().zip(self.u.iter()).map(|(a, b)| a * b).sum::<f32>();
        crate::tensor::ops::axpy_slice(g, coef, &self.v);
        self.last_flops += 2 * (dl_dh.len() + g.len()) as u64;
    }

    fn flush(&mut self, _theta: &[f32], _g: &mut [f32]) {}

    fn tracking_flops_per_step(&self) -> u64 {
        self.last_flops
    }

    fn tracking_memory_floats(&self) -> usize {
        self.u.len() + self.v.len()
    }

    fn save_state(&self, w: &mut Writer) {
        w.put_u8(state_tags::UORO);
        // The ν sign stream is part of the estimator's state: resuming with
        // a reseeded stream would be a *different* (still unbiased) run, not
        // a bitwise continuation.
        let (state, inc) = self.rng.state_parts();
        w.put_u64(state);
        w.put_u64(inc);
        w.put_f32s(&self.s);
        w.put_f32s(&self.u);
        w.put_f32s(&self.v);
    }

    fn load_state(&mut self, r: &mut Reader<'_>) -> Result<()> {
        check_state_tag(r.get_u8()?, state_tags::UORO, "uoro")?;
        let state = r.get_u64()?;
        let inc = r.get_u64()?;
        let s = r.get_f32s()?;
        let u = r.get_f32s()?;
        let v = r.get_f32s()?;
        crate::ensure!(
            s.len() == self.s.len() && u.len() == self.u.len() && v.len() == self.v.len(),
            "UORO state shape mismatch: checkpoint ({}, {}, {}) vs run ({}, {}, {})",
            s.len(),
            u.len(),
            v.len(),
            self.s.len(),
            self.u.len(),
            self.v.len()
        );
        self.rng = Pcg32::from_parts(state, inc);
        self.s = s;
        self.u = u;
        self.v = v;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::Arch;
    use crate::grad::rtrl::Rtrl;
    use crate::tensor::rng::Pcg32;

    /// UORO is *unbiased*: averaging the gradient estimate over many sign
    /// draws must converge to the exact RTRL gradient.
    #[test]
    fn mean_estimate_approaches_rtrl() {
        let mut rng = Pcg32::seeded(800);
        let (k, input, steps) = (4, 2, 3);
        let cell = Arch::Vanilla.build(k, input, 1.0, &mut rng);
        let theta = cell.init_params(&mut rng);
        let xs: Vec<Vec<f32>> =
            (0..steps).map(|_| (0..input).map(|_| rng.normal()).collect()).collect();
        let cs: Vec<Vec<f32>> =
            (0..steps).map(|_| (0..k).map(|_| rng.normal()).collect()).collect();

        let mut rtrl = Rtrl::new(cell.as_ref(), false);
        let mut g_exact = vec![0.0f32; cell.num_params()];
        for t in 0..steps {
            rtrl.step(&theta, &xs[t]);
            rtrl.inject_loss(&cs[t], &mut g_exact);
        }

        let trials = 4000;
        let mut g_mean = vec![0.0f64; cell.num_params()];
        for trial in 0..trials {
            let mut uoro = Uoro::new(cell.as_ref(), Pcg32::seeded(9000 + trial));
            let mut g = vec![0.0f32; cell.num_params()];
            for t in 0..steps {
                uoro.step(&theta, &xs[t]);
                uoro.inject_loss(&cs[t], &mut g);
            }
            for (m, x) in g_mean.iter_mut().zip(&g) {
                *m += *x as f64 / trials as f64;
            }
        }
        // Compare direction: cosine similarity of the mean to the exact grad.
        let dot: f64 = g_mean.iter().zip(&g_exact).map(|(a, &b)| a * b as f64).sum();
        let na: f64 = g_mean.iter().map(|a| a * a).sum::<f64>().sqrt();
        let nb: f64 = g_exact.iter().map(|&b| (b as f64) * (b as f64)).sum::<f64>().sqrt();
        let cos = dot / (na * nb).max(1e-12);
        assert!(cos > 0.9, "mean UORO estimate should align with RTRL: cos={cos}");
    }

    #[test]
    fn single_estimate_is_noisy() {
        // The known pathology (§1): one-sample UORO is far from the truth.
        let mut rng = Pcg32::seeded(801);
        let (k, input, steps) = (4, 2, 3);
        let cell = Arch::Vanilla.build(k, input, 1.0, &mut rng);
        let theta = cell.init_params(&mut rng);
        let xs: Vec<Vec<f32>> =
            (0..steps).map(|_| (0..input).map(|_| rng.normal()).collect()).collect();
        let cs: Vec<Vec<f32>> =
            (0..steps).map(|_| (0..k).map(|_| rng.normal()).collect()).collect();

        let mut rtrl = Rtrl::new(cell.as_ref(), false);
        let mut g_exact = vec![0.0f32; cell.num_params()];
        let mut uoro = Uoro::new(cell.as_ref(), Pcg32::seeded(123));
        let mut g_est = vec![0.0f32; cell.num_params()];
        for t in 0..steps {
            rtrl.step(&theta, &xs[t]);
            rtrl.inject_loss(&cs[t], &mut g_exact);
            uoro.step(&theta, &xs[t]);
            uoro.inject_loss(&cs[t], &mut g_est);
        }
        let err: f32 =
            g_est.iter().zip(&g_exact).map(|(a, b)| (a - b) * (a - b)).sum::<f32>().sqrt();
        let nrm: f32 = g_exact.iter().map(|b| b * b).sum::<f32>().sqrt();
        assert!(err / nrm.max(1e-9) > 0.1, "one-sample UORO is expected to be noisy");
    }

    #[test]
    fn memory_is_k_plus_p() {
        let mut rng = Pcg32::seeded(802);
        let cell = Arch::Gru.build(10, 4, 1.0, &mut rng);
        let uoro = Uoro::new(cell.as_ref(), Pcg32::seeded(1));
        assert_eq!(uoro.tracking_memory_floats(), cell.state_size() + cell.num_params());
    }

    #[test]
    fn factors_stay_finite_over_long_runs() {
        let mut rng = Pcg32::seeded(803);
        let cell = Arch::Gru.build(8, 3, 1.0, &mut rng);
        let theta = cell.init_params(&mut rng);
        let mut uoro = Uoro::new(cell.as_ref(), Pcg32::seeded(7));
        for _ in 0..500 {
            let x: Vec<f32> = (0..3).map(|_| rng.normal()).collect();
            uoro.step(&theta, &x);
        }
        let (u, v) = uoro.factors();
        assert!(u.iter().all(|a| a.is_finite()));
        assert!(v.iter().all(|a| a.is_finite()));
    }
}
