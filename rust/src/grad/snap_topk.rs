//! SnAp-TopK — the alternative sparsification strategy sketched in §3 of the
//! paper: "perform the full multiplication of `D_t·J_{t-1}` and then only
//! keep the top-k values. This would reduce the bias of the approximation
//! but increase its cost."
//!
//! Implemented as an ablation baseline: the influence matrix is tracked
//! densely (full `D·J` product at sparse-RTRL cost — D is a CSR
//! [`DynJacobian`], J stays dense) and after every update each column is
//! re-sparsified to its `budget` largest-magnitude entries. With `budget`
//! equal to SnAp-n's per-column pattern size, this isolates the value of
//! *adaptive* patterns over SnAp's fixed n-step pattern at matched storage.
//! (`repro`'s bench `step_costs` shows why the paper rejected it: the dense
//! J keeps the full `d·k²p` product plus a `k·p` selection pass, vs SnAp's
//! pattern-restricted `Σ|R_j|²`.)

use crate::cells::Cell;
use crate::errors::Result;
use crate::grad::{check_state_tag, state_tags, GradAlgo};
use crate::runtime::serde::{Reader, Writer};
use crate::sparse::dynjac::DynJacobian;
use crate::sparse::immediate::ImmediateJac;
use crate::tensor::matrix::Matrix;

pub struct SnapTopK<'c> {
    cell: &'c dyn Cell,
    s: Vec<f32>,
    j: Matrix,
    j_next: Matrix,
    d: DynJacobian,
    i_jac: ImmediateJac,
    cache: crate::cells::Cache,
    /// kept entries per column
    budget: usize,
    /// scratch for per-column selection
    col_scratch: Vec<(f32, u32)>,
    /// persistent next-state scratch (never serialized)
    s_next: Vec<f32>,
    last_flops: u64,
}

impl<'c> SnapTopK<'c> {
    pub fn new(cell: &'c dyn Cell, budget: usize) -> Self {
        let ss = cell.state_size();
        let p = cell.num_params();
        assert!(budget >= 1);
        SnapTopK {
            cell,
            s: vec![0.0; ss],
            j: Matrix::zeros(ss, p),
            j_next: Matrix::zeros(ss, p),
            d: cell.make_dyn_jacobian(),
            i_jac: cell.immediate_structure(),
            cache: cell.make_cache(),
            budget: budget.min(ss),
            col_scratch: Vec::with_capacity(ss),
            s_next: vec![0.0; ss],
            last_flops: 0,
        }
    }

    /// Budget matched to a SnAp-n pattern's mean column occupancy.
    pub fn budget_from_snap(cell: &'c dyn Cell, n: usize) -> usize {
        let i_pat = cell.immediate_structure().pattern();
        let pat = crate::sparse::pattern::snap_pattern(&cell.dynamics_pattern(), &i_pat, n);
        (pat.nnz() + pat.cols() - 1) / pat.cols().max(1)
    }

    pub fn influence(&self) -> &Matrix {
        &self.j
    }

    /// Current nnz of the (column-sparsified) influence matrix.
    pub fn influence_nnz(&self) -> usize {
        self.j.nnz(0.0)
    }

    /// Tag the dynamics Jacobian's [`SparseKernel`](crate::sparse::SparseKernel)
    /// implementation (construction-time choice — see `SparsityPlan::kernel`).
    pub fn set_kernel(&mut self, kernel: crate::sparse::simd::KernelKind) {
        self.d.set_kernel(kernel);
    }
}

impl GradAlgo for SnapTopK<'_> {
    fn name(&self) -> String {
        format!("snap-top{}", self.budget)
    }

    fn reset(&mut self) {
        self.s.iter_mut().for_each(|v| *v = 0.0);
        self.j.fill(0.0);
    }

    // audit: hot-path
    fn step(&mut self, theta: &[f32], x: &[f32]) {
        let ss = self.cell.state_size();
        let p = self.cell.num_params();
        // Allocation-free: forward into the owned scratch, then swap.
        self.cell.forward(theta, &self.s, x, &mut self.cache, &mut self.s_next);
        std::mem::swap(&mut self.s, &mut self.s_next);
        self.cell.dynamics(theta, &self.cache, &mut self.d);
        self.cell.immediate(&self.cache, &mut self.i_jac);

        // full product over D's structural nonzeros (the J side stays dense
        // — that is the cost the fixed pattern avoids)
        self.d.spmm_into(&self.j, &mut self.j_next, false);
        for jcol in 0..p {
            let (rows, vals) = self.i_jac.col(jcol);
            for (&i, &v) in rows.iter().zip(vals) {
                self.j_next.add_at(i as usize, jcol, v);
            }
        }
        // per-column top-k re-sparsification
        if self.budget < ss {
            for jcol in 0..p {
                self.col_scratch.clear();
                for i in 0..ss {
                    let v = self.j_next.get(i, jcol);
                    if v != 0.0 {
                        self.col_scratch.push((v.abs(), i as u32));
                    }
                }
                if self.col_scratch.len() > self.budget {
                    let b = self.budget;
                    self.col_scratch
                        .select_nth_unstable_by(b - 1, |a, x| x.0.partial_cmp(&a.0).unwrap());
                    for &(_, i) in &self.col_scratch[b..] {
                        self.j_next.set(i as usize, jcol, 0.0);
                    }
                }
            }
        }
        std::mem::swap(&mut self.j, &mut self.j_next);
        self.last_flops = 2 * self.d.nnz() as u64 * p as u64 + (ss * p) as u64;
    }

    fn hidden(&self) -> &[f32] {
        &self.s[..self.cell.hidden_size()]
    }

    fn state(&self) -> &[f32] {
        &self.s
    }

    // audit: hot-path
    fn inject_loss(&mut self, dl_dh: &[f32], g: &mut [f32]) {
        for (i, &di) in dl_dh.iter().enumerate() {
            if di != 0.0 {
                crate::tensor::ops::axpy_slice(g, di, self.j.row(i));
            }
        }
    }

    fn flush(&mut self, _theta: &[f32], _g: &mut [f32]) {}

    fn tracking_flops_per_step(&self) -> u64 {
        self.last_flops
    }

    fn tracking_memory_floats(&self) -> usize {
        // storage could be compressed to budget·p; dense here for simplicity
        self.budget * self.cell.num_params()
    }

    fn save_state(&self, w: &mut Writer) {
        w.put_u8(state_tags::SNAP_TOPK);
        w.put_u64(self.budget as u64);
        w.put_f32s(&self.s);
        // The kept pattern is adaptive (top-k per column per step), so the
        // dense J — zeros included — is the canonical representation.
        w.put_f32s(self.j.as_slice());
    }

    fn load_state(&mut self, r: &mut Reader<'_>) -> Result<()> {
        check_state_tag(r.get_u8()?, state_tags::SNAP_TOPK, &self.name())?;
        let budget = r.get_u64()? as usize;
        crate::ensure!(
            budget == self.budget,
            "SnAp-TopK budget mismatch: checkpoint {budget} vs run {}",
            self.budget
        );
        let s = r.get_f32s()?;
        crate::ensure!(
            s.len() == self.s.len(),
            "SnAp-TopK state length mismatch: checkpoint {} vs run {}",
            s.len(),
            self.s.len()
        );
        let j = r.get_f32s()?;
        crate::ensure!(
            j.len() == self.j.len(),
            "SnAp-TopK influence size mismatch: checkpoint {} vs run {}",
            j.len(),
            self.j.len()
        );
        self.s = s;
        self.j.as_mut_slice().copy_from_slice(&j);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::Arch;
    use crate::grad::rtrl::Rtrl;
    use crate::grad::snap::Snap;
    use crate::tensor::rng::Pcg32;

    fn cos_dist(a: &[f32], b: &[f32]) -> f64 {
        let dot: f64 = a.iter().zip(b).map(|(x, y)| *x as f64 * *y as f64).sum();
        let na: f64 = a.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
        let nb: f64 = b.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
        1.0 - dot / (na * nb).max(1e-300)
    }

    fn run<A: GradAlgo + ?Sized>(
        algo: &mut A,
        theta: &[f32],
        xs: &[Vec<f32>],
        cs: &[Vec<f32>],
        p: usize,
    ) -> Vec<f32> {
        let mut g = vec![0.0f32; p];
        for (x, c) in xs.iter().zip(cs) {
            algo.step(theta, x);
            algo.inject_loss(c, &mut g);
        }
        g
    }

    #[test]
    fn full_budget_equals_rtrl() {
        let mut rng = Pcg32::seeded(1500);
        let cell = Arch::Gru.build(6, 3, 0.4, &mut rng);
        let theta = cell.init_params(&mut rng);
        let xs: Vec<Vec<f32>> = (0..5).map(|_| (0..3).map(|_| rng.normal()).collect()).collect();
        let cs: Vec<Vec<f32>> = (0..5).map(|_| (0..6).map(|_| rng.normal()).collect()).collect();
        let p = cell.num_params();
        let g_top = run(&mut SnapTopK::new(cell.as_ref(), 6), &theta, &xs, &cs, p);
        let g_rtrl = run(&mut Rtrl::new(cell.as_ref(), false), &theta, &xs, &cs, p);
        assert!(crate::testing::max_rel_dev(&g_top, &g_rtrl) < 1e-4);
    }

    #[test]
    fn topk_no_more_biased_than_fixed_pattern_at_matched_budget() {
        // The paper's claim: adaptive top-k "would reduce the bias".
        let mut rng = Pcg32::seeded(1501);
        let cell = Arch::Gru.build(8, 3, 0.3, &mut rng);
        let theta = cell.init_params(&mut rng);
        let xs: Vec<Vec<f32>> = (0..8).map(|_| (0..3).map(|_| rng.normal()).collect()).collect();
        let cs: Vec<Vec<f32>> = (0..8).map(|_| (0..8).map(|_| rng.normal()).collect()).collect();
        let p = cell.num_params();
        let g_rtrl = run(&mut Rtrl::new(cell.as_ref(), false), &theta, &xs, &cs, p);

        let budget = SnapTopK::budget_from_snap(cell.as_ref(), 2);
        let g_top = run(&mut SnapTopK::new(cell.as_ref(), budget), &theta, &xs, &cs, p);
        let g_snap2 = run(&mut Snap::new(cell.as_ref(), 2), &theta, &xs, &cs, p);

        let d_top = cos_dist(&g_top, &g_rtrl);
        let d_snap = cos_dist(&g_snap2, &g_rtrl);
        assert!(
            d_top <= d_snap + 0.02,
            "top-k (d={d_top:.4}) should not be much worse than snap-2 (d={d_snap:.4})"
        );
    }

    #[test]
    fn column_budget_is_enforced() {
        let mut rng = Pcg32::seeded(1502);
        let cell = Arch::Vanilla.build(8, 2, 1.0, &mut rng);
        let theta = cell.init_params(&mut rng);
        let mut algo = SnapTopK::new(cell.as_ref(), 2);
        for _ in 0..4 {
            algo.step(&theta, &[0.5, -0.5]);
        }
        let j = algo.influence();
        for col in 0..cell.num_params() {
            let nnz = (0..8).filter(|&i| j.get(i, col) != 0.0).count();
            assert!(nnz <= 2, "column {col} has {nnz} > 2 entries");
        }
    }
}
