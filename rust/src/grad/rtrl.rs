//! Real-Time Recurrent Learning (paper §2.1) and its sparse-network
//! optimization (§3.2).
//!
//! Tracks the exact influence matrix `J_t = ∂s_t/∂θ` (state × p, dense) via
//! `J_t = I_t + D_t·J_{t-1}`. Under the sparse-D contract, `D_t` is a CSR
//! [`DynJacobian`] and the product is a CSR×dense `spmm` — eq. 4's
//! `J̃_t = Ĩ_t + D_t·J̃_{t-1}` with cost `d·(d·k²·p)` instead of `k²·p` (the
//! column compression onto kept parameters is already built into the cells'
//! θ layout). The `sparse_dynamics` flag is now purely a naming/accounting
//! distinction (`rtrl` vs `sparse-rtrl` — the gradients were always
//! identical); both variants run the same sparse kernel, and on a dense
//! network the CSR structure is dense so nothing is lost.

use crate::cells::Cell;
use crate::errors::Result;
use crate::grad::{check_state_tag, state_tags, GradAlgo};
use crate::runtime::serde::{Reader, Writer};
use crate::sparse::dynjac::DynJacobian;
use crate::sparse::immediate::ImmediateJac;
use crate::tensor::matrix::Matrix;

pub struct Rtrl<'c> {
    cell: &'c dyn Cell,
    s: Vec<f32>,
    /// influence matrix J (state × p)
    j: Matrix,
    j_next: Matrix,
    d: DynJacobian,
    i_jac: ImmediateJac,
    cache: crate::cells::Cache,
    sparse_dynamics: bool,
    /// persistent next-state scratch (never serialized)
    s_next: Vec<f32>,
    last_flops: u64,
}

impl<'c> Rtrl<'c> {
    pub fn new(cell: &'c dyn Cell, sparse_dynamics: bool) -> Self {
        let ss = cell.state_size();
        let p = cell.num_params();
        Rtrl {
            cell,
            s: vec![0.0; ss],
            j: Matrix::zeros(ss, p),
            j_next: Matrix::zeros(ss, p),
            d: cell.make_dyn_jacobian(),
            i_jac: cell.immediate_structure(),
            cache: cell.make_cache(),
            sparse_dynamics,
            s_next: vec![0.0; ss],
            last_flops: 0,
        }
    }

    /// Read-only view of the exact influence matrix (Figure 6 / Table 4
    /// analysis).
    pub fn influence(&self) -> &Matrix {
        &self.j
    }

    /// Tag the dynamics Jacobian's [`SparseKernel`](crate::sparse::SparseKernel)
    /// implementation (construction-time choice — see `SparsityPlan::kernel`).
    pub fn set_kernel(&mut self, kernel: crate::sparse::simd::KernelKind) {
        self.d.set_kernel(kernel);
    }
}

impl GradAlgo for Rtrl<'_> {
    fn name(&self) -> String {
        if self.sparse_dynamics {
            "sparse-rtrl".into()
        } else {
            "rtrl".into()
        }
    }

    fn reset(&mut self) {
        self.s.iter_mut().for_each(|v| *v = 0.0);
        self.j.fill(0.0);
    }

    // audit: hot-path
    fn step(&mut self, theta: &[f32], x: &[f32]) {
        let p = self.cell.num_params();
        // Allocation-free: forward into the owned scratch, then swap.
        self.cell.forward(theta, &self.s, x, &mut self.cache, &mut self.s_next);
        std::mem::swap(&mut self.s, &mut self.s_next);
        self.cell.dynamics(theta, &self.cache, &mut self.d);
        self.cell.immediate(&self.cache, &mut self.i_jac);

        // J_next = D · J: CSR × dense spmm over D's structural nonzeros.
        self.d.spmm_into(&self.j, &mut self.j_next, false);
        self.last_flops = 2 * self.d.nnz() as u64 * p as u64;
        // J_next += I (scatter of ≤2 entries per column)
        for jcol in 0..p {
            let (rows, vals) = self.i_jac.col(jcol);
            for (&i, &v) in rows.iter().zip(vals) {
                self.j_next.add_at(i as usize, jcol, v);
            }
        }
        self.last_flops += self.i_jac.nnz() as u64;
        std::mem::swap(&mut self.j, &mut self.j_next);
    }

    fn hidden(&self) -> &[f32] {
        &self.s[..self.cell.hidden_size()]
    }

    fn state(&self) -> &[f32] {
        &self.s
    }

    // audit: hot-path
    fn inject_loss(&mut self, dl_dh: &[f32], g: &mut [f32]) {
        // g += (∂L/∂s)·J, with ∂L/∂s = [dl_dh ; 0] (loss reads h only).
        debug_assert_eq!(dl_dh.len(), self.cell.hidden_size());
        for (i, &di) in dl_dh.iter().enumerate() {
            if di != 0.0 {
                crate::tensor::ops::axpy_slice(g, di, self.j.row(i));
            }
        }
        self.last_flops += 2 * dl_dh.len() as u64 * self.cell.num_params() as u64;
    }

    fn flush(&mut self, _theta: &[f32], _g: &mut [f32]) {}

    fn tracking_flops_per_step(&self) -> u64 {
        self.last_flops
    }

    fn tracking_memory_floats(&self) -> usize {
        self.j.len() + self.d.nnz()
    }

    fn save_state(&self, w: &mut Writer) {
        w.put_u8(state_tags::RTRL);
        w.put_bool(self.sparse_dynamics);
        w.put_f32s(&self.s);
        // Full dense influence J (state × p). The sparse D and all scratch
        // buffers are refreshed every step, so only the structure-free
        // state travels (blob format unchanged across the sparse-D refactor).
        w.put_f32s(self.j.as_slice());
    }

    fn load_state(&mut self, r: &mut Reader<'_>) -> Result<()> {
        check_state_tag(r.get_u8()?, state_tags::RTRL, &self.name())?;
        let sparse = r.get_bool()?;
        crate::ensure!(
            sparse == self.sparse_dynamics,
            "RTRL variant mismatch: checkpoint '{}' vs run '{}'",
            if sparse { "sparse-rtrl" } else { "rtrl" },
            self.name()
        );
        let s = r.get_f32s()?;
        crate::ensure!(
            s.len() == self.s.len(),
            "RTRL state length mismatch: checkpoint {} vs run {}",
            s.len(),
            self.s.len()
        );
        let j = r.get_f32s()?;
        crate::ensure!(
            j.len() == self.j.len(),
            "RTRL influence size mismatch: checkpoint {} vs run {}",
            j.len(),
            self.j.len()
        );
        self.s = s;
        self.j.as_mut_slice().copy_from_slice(&j);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::Arch;
    use crate::grad::bptt::Bptt;
    use crate::tensor::rng::Pcg32;

    /// The fundamental identity: RTRL and BPTT compute the *same* gradient
    /// (eq. 1 == eq. 2) when the parameters are held fixed over the sequence.
    fn rtrl_equals_bptt(arch: Arch, density: f64, sparse_dynamics: bool) {
        let mut rng = Pcg32::seeded(600);
        let (k, input, steps) = (6, 3, 7);
        let cell = arch.build(k, input, density, &mut rng);
        let theta = cell.init_params(&mut rng);
        let xs: Vec<Vec<f32>> =
            (0..steps).map(|_| (0..input).map(|_| rng.normal()).collect()).collect();
        let cs: Vec<Vec<f32>> =
            (0..steps).map(|_| (0..cell.hidden_size()).map(|_| rng.normal()).collect()).collect();

        let mut rtrl = Rtrl::new(cell.as_ref(), sparse_dynamics);
        let mut g_rtrl = vec![0.0f32; cell.num_params()];
        for t in 0..steps {
            rtrl.step(&theta, &xs[t]);
            rtrl.inject_loss(&cs[t], &mut g_rtrl);
        }

        let mut bptt = Bptt::new(cell.as_ref());
        let mut g_bptt = vec![0.0f32; cell.num_params()];
        for t in 0..steps {
            bptt.step(&theta, &xs[t]);
            bptt.inject_loss(&cs[t], &mut g_bptt);
        }
        bptt.flush(&theta, &mut g_bptt);

        let scale = g_bptt.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-6);
        for (j, (a, b)) in g_rtrl.iter().zip(g_bptt.iter()).enumerate() {
            assert!(
                (a - b).abs() / scale < 1e-4,
                "{arch:?} sd={sparse_dynamics} param {j}: rtrl={a} bptt={b}"
            );
        }
    }

    #[test]
    fn rtrl_equals_bptt_vanilla() {
        rtrl_equals_bptt(Arch::Vanilla, 1.0, false);
        rtrl_equals_bptt(Arch::Vanilla, 0.4, false);
    }

    #[test]
    fn rtrl_equals_bptt_gru() {
        rtrl_equals_bptt(Arch::Gru, 1.0, false);
        rtrl_equals_bptt(Arch::Gru, 0.4, false);
    }

    #[test]
    fn rtrl_equals_bptt_lstm() {
        rtrl_equals_bptt(Arch::Lstm, 1.0, false);
        rtrl_equals_bptt(Arch::Lstm, 0.4, false);
    }

    #[test]
    fn sparse_dynamics_is_exact() {
        // §3.2: the sparse optimization changes cost, not the result.
        rtrl_equals_bptt(Arch::Vanilla, 0.3, true);
        rtrl_equals_bptt(Arch::Gru, 0.3, true);
        rtrl_equals_bptt(Arch::Lstm, 0.3, true);
    }

    #[test]
    fn reset_zeroes_influence() {
        let mut rng = Pcg32::seeded(601);
        let cell = Arch::Gru.build(4, 2, 1.0, &mut rng);
        let theta = cell.init_params(&mut rng);
        let mut rtrl = Rtrl::new(cell.as_ref(), false);
        rtrl.step(&theta, &[1.0, -1.0]);
        assert!(rtrl.influence().norm() > 0.0);
        rtrl.reset();
        assert_eq!(rtrl.influence().norm(), 0.0);
        assert!(rtrl.state().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn flops_track_dynamics_sparsity() {
        // Under the sparse-D contract the D·J cost is 2·nnz(D)·p, so a
        // sparse network is charged (and does) far less work than a dense
        // one of the same size — the §3.2 saving, measured.
        let mut rng = Pcg32::seeded(602);
        let dense_cell = Arch::Vanilla.build(16, 4, 1.0, &mut rng);
        let sparse_cell = Arch::Vanilla.build(16, 4, 0.2, &mut rng);
        let x = vec![0.0f32; 4];
        let theta_d = dense_cell.init_params(&mut rng);
        let theta_s = sparse_cell.init_params(&mut rng);
        let mut dense = Rtrl::new(dense_cell.as_ref(), false);
        let mut sparse = Rtrl::new(sparse_cell.as_ref(), true);
        dense.step(&theta_d, &x);
        sparse.step(&theta_s, &x);
        let per_param_dense = dense.tracking_flops_per_step() / dense_cell.num_params() as u64;
        let per_param_sparse = sparse.tracking_flops_per_step() / sparse_cell.num_params() as u64;
        assert!(
            per_param_sparse < per_param_dense / 2,
            "sparse={per_param_sparse} dense={per_param_dense}"
        );
    }
}
