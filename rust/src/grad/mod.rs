//! Gradient algorithms for recurrent parameters.
//!
//! All six methods of the paper share one interface ([`GradAlgo`]) so the
//! trainer can swap them freely:
//!
//! | method       | paper | tracking state                   | per-step cost    |
//! |--------------|-------|----------------------------------|------------------|
//! | [`Bptt`]     | §2    | stored caches (window)           | `k² + p`         |
//! | [`Rtrl`]     | §2.1  | dense `J (state×p)`              | `k²·p`           |
//! | sparse RTRL  | §3.2  | dense `J̃`, CSR `D`              | `d·k²·p`         |
//! | [`Snap`]     | §3    | `J̃` on the n-step pattern       | `Σ_j |R_j|²`     |
//! | [`Uoro`]     | §4    | rank-1 `ũ ṽᵀ`                   | `k² + p`         |
//! | [`Rflo`]     | §4    | `J` on the I-pattern             | `p`              |
//!
//! Protocol per timestep (the trainer drives this):
//! 1. `step(theta, x)` — advance the recurrent state, update the tracking
//!    quantities.
//! 2. compute the loss on `hidden()`, backprop the readout to get
//!    `∂L_t/∂h_t`, call `inject_loss(dl_dh, g)`.
//! 3. (BPTT only) `flush(theta, g)` materializes deferred gradients — at
//!    every step for fully-online T=1, or at the window boundary otherwise.
//!
//! `reset()` marks a sequence boundary: state and influence go to zero.
//! Weight updates *between* steps leave the influence in place — that is the
//! paper's "stale Jacobian" fully-online regime (§2.2).

pub mod bptt;
pub mod rtrl;
pub mod snap;
pub mod snap_topk;
pub mod uoro;
pub mod rflo;

pub use bptt::Bptt;
pub use rtrl::Rtrl;
pub use snap::Snap;
pub use snap_topk::SnapTopK;
pub use uoro::Uoro;
pub use rflo::Rflo;

use crate::cells::Cell;
use crate::tensor::rng::Pcg32;

/// Uniform interface over the gradient algorithms.
///
/// `Send` is a supertrait so a `Box<dyn GradAlgo>` can be moved into (or
/// mutably borrowed across) the lane-parallel executor's worker threads
/// (`train::executor`). Every implementor is plain owned data plus a
/// `&dyn Cell` (and `Cell: Sync`), so the bound is automatic.
pub trait GradAlgo: Send {
    fn name(&self) -> String;

    /// Sequence boundary: zero the recurrent state and all influence tracking.
    fn reset(&mut self);

    /// Advance one timestep with the current parameters.
    fn step(&mut self, theta: &[f32], x: &[f32]);

    /// Hidden vector exposed to the readout (length `cell.hidden_size()`).
    fn hidden(&self) -> &[f32];

    /// Full recurrent state (length `cell.state_size()`).
    fn state(&self) -> &[f32];

    /// Accumulate this step's loss gradient `∂L_t/∂h_t` into `g`
    /// (length = number of tracked recurrent params). RTRL-family methods
    /// contract against their influence estimate immediately; BPTT defers.
    fn inject_loss(&mut self, dl_dh: &[f32], g: &mut [f32]);

    /// Materialize any deferred gradient (BPTT backward). No-op for the
    /// forward-mode methods.
    fn flush(&mut self, theta: &[f32], g: &mut [f32]);

    /// Exact FLOPs consumed by tracking (excl. cell forward) in the last
    /// `step` + `inject_loss` pair — drives Table 3.
    fn tracking_flops_per_step(&self) -> u64;

    /// f32 slots held by the tracking state — drives Table 1's memory column.
    fn tracking_memory_floats(&self) -> usize;
}

/// Which algorithm to build — the coordinator's config surface.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Method {
    Bptt,
    Rtrl,
    /// RTRL with the §3.2 sparse-dynamics optimization.
    SparseRtrl,
    /// SnAp-n (n >= 1).
    Snap(usize),
    /// §3's alternative: full product + per-column top-k (ablation).
    SnapTopK(usize),
    Uoro,
    Rflo,
    /// Readout-only baseline: recurrent params left at init (Fig. 3's
    /// surprisingly strong "not training the recurrent parameters" baseline).
    Frozen,
}

impl Method {
    pub fn name(&self) -> String {
        match self {
            Method::Bptt => "bptt".into(),
            Method::Rtrl => "rtrl".into(),
            Method::SparseRtrl => "sparse-rtrl".into(),
            Method::Snap(n) => format!("snap-{n}"),
            Method::SnapTopK(b) => format!("snap-topk-{b}"),
            Method::Uoro => "uoro".into(),
            Method::Rflo => "rflo".into(),
            Method::Frozen => "frozen".into(),
        }
    }

    pub fn parse(s: &str) -> Option<Method> {
        let s = s.to_ascii_lowercase();
        match s.as_str() {
            "bptt" => Some(Method::Bptt),
            "rtrl" => Some(Method::Rtrl),
            "sparse-rtrl" | "sparsertrl" => Some(Method::SparseRtrl),
            "uoro" => Some(Method::Uoro),
            "rflo" => Some(Method::Rflo),
            "frozen" => Some(Method::Frozen),
            _ => s
                .strip_prefix("snap-topk-")
                .and_then(|n| n.parse().ok())
                .filter(|&n| n >= 1)
                .map(Method::SnapTopK)
                .or_else(|| s
                .strip_prefix("snap-")
                .or_else(|| s.strip_prefix("snap"))
                .and_then(|n| n.parse().ok())
                .filter(|&n| n >= 1)
                .map(Method::Snap)),
        }
    }

    /// Instantiate the algorithm for `cell`. The returned box is `Send`
    /// (via `GradAlgo`'s supertrait), so one instance per minibatch lane can
    /// be driven from a worker thread while all lanes share `&cell`.
    pub fn build<'c>(&self, cell: &'c dyn Cell, rng: &mut Pcg32) -> Box<dyn GradAlgo + 'c> {
        match *self {
            Method::Bptt | Method::Frozen => Box::new(Bptt::new(cell)),
            Method::Rtrl => Box::new(Rtrl::new(cell, false)),
            Method::SparseRtrl => Box::new(Rtrl::new(cell, true)),
            Method::Snap(n) => Box::new(Snap::new(cell, n)),
            Method::SnapTopK(b) => Box::new(SnapTopK::new(cell, b)),
            Method::Uoro => Box::new(Uoro::new(cell, rng.split(0x714c))),
            Method::Rflo => Box::new(Rflo::new(cell, 1.0)),
        }
    }

    /// Frozen trains the readout only.
    pub fn trains_recurrent(&self) -> bool {
        !matches!(self, Method::Frozen)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parsing() {
        assert_eq!(Method::parse("bptt"), Some(Method::Bptt));
        assert_eq!(Method::parse("snap-1"), Some(Method::Snap(1)));
        assert_eq!(Method::parse("SnAp-3"), Some(Method::Snap(3)));
        assert_eq!(Method::parse("snap-0"), None);
        assert_eq!(Method::parse("uoro"), Some(Method::Uoro));
        assert_eq!(Method::parse("nope"), None);
        assert_eq!(Method::Snap(2).name(), "snap-2");
        assert_eq!(Method::parse("snap-topk-4"), Some(Method::SnapTopK(4)));
        assert_eq!(Method::SnapTopK(4).name(), "snap-topk-4");
    }
}
