//! Gradient algorithms for recurrent parameters.
//!
//! All six methods of the paper share one interface ([`GradAlgo`]) so the
//! trainer can swap them freely:
//!
//! | method       | paper | tracking state                   | per-step cost    |
//! |--------------|-------|----------------------------------|------------------|
//! | [`Bptt`]     | §2    | stored caches (window)           | `k² + p`         |
//! | [`Rtrl`]     | §2.1  | dense `J (state×p)`              | `k²·p`           |
//! | sparse RTRL  | §3.2  | dense `J̃`, CSR `D`              | `d·k²·p`         |
//! | [`Snap`]     | §3    | `J̃` on the n-step pattern       | `Σ_j |R_j|²`     |
//! | [`Uoro`]     | §4    | rank-1 `ũ ṽᵀ`                   | `k² + p`         |
//! | [`Rflo`]     | §4    | `J` on the I-pattern             | `p`              |
//!
//! Protocol per timestep (the trainer drives this):
//! 1. `step(theta, x)` — advance the recurrent state, update the tracking
//!    quantities.
//! 2. compute the loss on `hidden()`, backprop the readout to get
//!    `∂L_t/∂h_t`, call `inject_loss(dl_dh, g)`.
//! 3. (BPTT only) `flush(theta, g)` materializes deferred gradients — at
//!    every step for fully-online T=1, or at the window boundary otherwise.
//!
//! `reset()` marks a sequence boundary: state and influence go to zero.
//! Weight updates *between* steps leave the influence in place — that is the
//! paper's "stale Jacobian" fully-online regime (§2.2).

pub mod bptt;
pub mod rtrl;
pub mod snap;
pub mod snap_topk;
pub mod uoro;
pub mod rflo;

pub use bptt::Bptt;
pub use rtrl::Rtrl;
pub use snap::Snap;
pub use snap_topk::SnapTopK;
pub use uoro::Uoro;
pub use rflo::Rflo;

use crate::cells::Cell;
use crate::errors::Result;
use crate::runtime::serde::{Reader, Writer};
use crate::sparse::simd::KernelKind;
use crate::tensor::rng::Pcg32;

/// Uniform interface over the gradient algorithms.
///
/// `Send` is a supertrait so a `Box<dyn GradAlgo>` can be moved into (or
/// mutably borrowed across) the lane-parallel executor's worker threads
/// (`train::executor`). Every implementor is plain owned data plus a
/// `&dyn Cell` (and `Cell: Sync`), so the bound is automatic.
pub trait GradAlgo: Send {
    fn name(&self) -> String;

    /// Sequence boundary: zero the recurrent state and all influence tracking.
    fn reset(&mut self);

    /// Advance one timestep with the current parameters.
    fn step(&mut self, theta: &[f32], x: &[f32]);

    /// Hidden vector exposed to the readout (length `cell.hidden_size()`).
    fn hidden(&self) -> &[f32];

    /// Full recurrent state (length `cell.state_size()`).
    fn state(&self) -> &[f32];

    /// Accumulate this step's loss gradient `∂L_t/∂h_t` into `g`
    /// (length = number of tracked recurrent params). RTRL-family methods
    /// contract against their influence estimate immediately; BPTT defers.
    fn inject_loss(&mut self, dl_dh: &[f32], g: &mut [f32]);

    /// Materialize any deferred gradient (BPTT backward). No-op for the
    /// forward-mode methods.
    fn flush(&mut self, theta: &[f32], g: &mut [f32]);

    /// Exact FLOPs consumed by tracking (excl. cell forward) in the last
    /// `step` + `inject_loss` pair — drives Table 3.
    fn tracking_flops_per_step(&self) -> u64;

    /// f32 slots held by the tracking state — drives Table 1's memory column.
    fn tracking_memory_floats(&self) -> usize;

    /// Bench A/B hook: force the historical two-pass influence update
    /// instead of the fused kernel. Only meaningful for SnAp's
    /// [`ColJacobian`](crate::sparse::ColJacobian)-backed tracking — the
    /// default is a no-op so every other method ignores it. Numerics are
    /// unchanged either way (the scalar fused kernel is bitwise-identical
    /// to the two-pass order).
    fn set_two_pass_update(&mut self, _two_pass: bool) {}

    /// Serialize the algorithm's complete mutable tracking state (recurrent
    /// state + influence estimate + any private RNG) into `w` — one blob per
    /// lane inside a training checkpoint (`train::checkpoint`). Every
    /// implementation leads with its own tag byte and shape/structure
    /// witnesses so a restore onto the wrong method, order or pattern fails
    /// loudly instead of silently corrupting training.
    ///
    /// Must be called at an **update boundary**: forward-mode methods
    /// (RTRL/SnAp/UORO/RFLO) are resumable at any such boundary; BPTT
    /// additionally requires its window to be flushed (always true at the
    /// drivers' step boundaries — see the per-method resume-granularity
    /// table in `train::checkpoint`).
    fn save_state(&self, w: &mut Writer);

    /// Restore a [`save_state`](GradAlgo::save_state) snapshot. Fails with a
    /// named error on a method, shape or pattern-fingerprint mismatch; on
    /// success the next `step` continues bit for bit.
    fn load_state(&mut self, r: &mut Reader<'_>) -> Result<()>;
}

/// Serialization tags: the first byte of every [`GradAlgo::save_state`]
/// blob, so restoring a checkpoint onto the wrong method is a named error
/// (verified through `runtime::serde`'s shared `check_state_tag`,
/// re-exported below for the implementations).
pub mod state_tags {
    pub const BPTT: u8 = 1;
    pub const RTRL: u8 = 2;
    pub const SNAP: u8 = 3;
    pub const SNAP_TOPK: u8 = 4;
    pub const UORO: u8 = 5;
    pub const RFLO: u8 = 6;
}

pub(crate) use crate::runtime::serde::check_state_tag;

/// Everything a [`Method`] needs *besides* the cell to instantiate its
/// algorithm: the per-lane sparsity/stochasticity decisions, captured as
/// plain data so every construction site (training lanes, the serve
/// runtime's sessions, cost probes) flows through one factory instead of
/// duplicating the method→constructor match.
///
/// The plan is deliberately tiny: SnAp's premise is that the *pattern* is a
/// property of the cell (`Cell::dynamics_pattern`), so the only per-instance
/// degrees of freedom are UORO's private sign-vector RNG stream and RFLO's
/// leak rate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SparsityPlan {
    /// RFLO's leak rate α (the drivers always use 1.0 — pure immediate
    /// Jacobian — matching the paper's RFLO baseline).
    pub rflo_leak: f32,
    /// UORO's private RNG stream as `(state, inc)` parts
    /// ([`Pcg32::state_parts`]). Ignored by every other method; a restored
    /// instance overwrites it from the checkpoint blob, so `(0, 1)` is a
    /// fine placeholder when a `load_state` follows.
    pub uoro_stream: (u64, u64),
    /// Which [`SparseKernel`](crate::sparse::SparseKernel) implementation the
    /// algorithm's dynamics Jacobian dispatches to. Defaults to
    /// [`KernelKind::Scalar`] (bit-for-bit the historical loops); the drivers
    /// resolve the user's `--kernel` choice once and thread it through here.
    pub kernel: KernelKind,
}

impl Default for SparsityPlan {
    fn default() -> Self {
        SparsityPlan { rflo_leak: 1.0, uoro_stream: (0, 1), kernel: KernelKind::Scalar }
    }
}

impl SparsityPlan {
    /// The drivers' plan for one lane: draw UORO's stream off the lane RNG
    /// (tag `0x714c`, the historical constant — so plans built here keep
    /// every existing run bitwise identical), touch the RNG for no other
    /// method.
    pub fn for_lane(method: Method, rng: &mut Pcg32) -> SparsityPlan {
        let uoro_stream = match method {
            Method::Uoro => rng.split(0x714c).state_parts(),
            _ => (0, 1),
        };
        SparsityPlan { rflo_leak: 1.0, uoro_stream, kernel: KernelKind::Scalar }
    }

    /// Same plan, different kernel — combinator form so construction sites
    /// can write `SparsityPlan::for_lane(m, rng).with_kernel(k)`.
    pub fn with_kernel(mut self, kernel: KernelKind) -> Self {
        self.kernel = kernel;
        self
    }
}

impl dyn GradAlgo {
    /// The single factory behind all six constructors: instantiate `method`
    /// for `cell` according to `plan`. Every construction site — the lane
    /// executor, the serve runtime's sessions, restore-from-blob paths —
    /// calls this (as `<dyn GradAlgo>::build(..)`) so the method→constructor
    /// match exists exactly once. The returned box is `Send` (supertrait),
    /// so one instance per lane/session can be driven from worker threads
    /// while all of them share `&cell`.
    pub fn build<'c>(
        method: Method,
        cell: &'c dyn Cell,
        plan: &SparsityPlan,
    ) -> Box<dyn GradAlgo + 'c> {
        match method {
            Method::Bptt | Method::Frozen => {
                let mut a = Bptt::new(cell);
                a.set_kernel(plan.kernel);
                Box::new(a)
            }
            Method::Rtrl => {
                let mut a = Rtrl::new(cell, false);
                a.set_kernel(plan.kernel);
                Box::new(a)
            }
            Method::SparseRtrl => {
                let mut a = Rtrl::new(cell, true);
                a.set_kernel(plan.kernel);
                Box::new(a)
            }
            Method::Snap(n) => {
                let mut a = Snap::new(cell, n);
                a.set_kernel(plan.kernel);
                Box::new(a)
            }
            Method::SnapTopK(b) => {
                let mut a = SnapTopK::new(cell, b);
                a.set_kernel(plan.kernel);
                Box::new(a)
            }
            Method::Uoro => {
                let mut a =
                    Uoro::new(cell, Pcg32::from_parts(plan.uoro_stream.0, plan.uoro_stream.1));
                a.set_kernel(plan.kernel);
                Box::new(a)
            }
            // RFLO tracks on the immediate-Jacobian pattern only — it never
            // touches a DynJacobian, so there is nothing to tag.
            Method::Rflo => Box::new(Rflo::new(cell, plan.rflo_leak)),
        }
    }
}

/// Which algorithm to build — the coordinator's config surface.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Method {
    Bptt,
    Rtrl,
    /// RTRL with the §3.2 sparse-dynamics optimization.
    SparseRtrl,
    /// SnAp-n (n >= 1).
    Snap(usize),
    /// §3's alternative: full product + per-column top-k (ablation).
    SnapTopK(usize),
    Uoro,
    Rflo,
    /// Readout-only baseline: recurrent params left at init (Fig. 3's
    /// surprisingly strong "not training the recurrent parameters" baseline).
    Frozen,
}

impl Method {
    pub fn name(&self) -> String {
        match self {
            Method::Bptt => "bptt".into(),
            Method::Rtrl => "rtrl".into(),
            Method::SparseRtrl => "sparse-rtrl".into(),
            Method::Snap(n) => format!("snap-{n}"),
            Method::SnapTopK(b) => format!("snap-topk-{b}"),
            Method::Uoro => "uoro".into(),
            Method::Rflo => "rflo".into(),
            Method::Frozen => "frozen".into(),
        }
    }

    pub fn parse(s: &str) -> Option<Method> {
        let s = s.to_ascii_lowercase();
        match s.as_str() {
            "bptt" => Some(Method::Bptt),
            "rtrl" => Some(Method::Rtrl),
            "sparse-rtrl" | "sparsertrl" => Some(Method::SparseRtrl),
            "uoro" => Some(Method::Uoro),
            "rflo" => Some(Method::Rflo),
            "frozen" => Some(Method::Frozen),
            _ => s
                .strip_prefix("snap-topk-")
                .and_then(|n| n.parse().ok())
                .filter(|&n| n >= 1)
                .map(Method::SnapTopK)
                .or_else(|| s
                .strip_prefix("snap-")
                .or_else(|| s.strip_prefix("snap"))
                .and_then(|n| n.parse().ok())
                .filter(|&n| n >= 1)
                .map(Method::Snap)),
        }
    }

    /// Instantiate the algorithm for `cell`: the lane-RNG convenience
    /// wrapper over the unified factory. Draws a [`SparsityPlan`] off `rng`
    /// ([`SparsityPlan::for_lane`] — only UORO consumes a draw) and defers
    /// to [`<dyn GradAlgo>::build`](GradAlgo#method.build), so this is
    /// bitwise identical to the historical per-method constructors.
    pub fn build<'c>(&self, cell: &'c dyn Cell, rng: &mut Pcg32) -> Box<dyn GradAlgo + 'c> {
        self.build_with_kernel(cell, rng, KernelKind::Scalar)
    }

    /// [`Method::build`] with an explicit sparse-kernel choice: the lane
    /// executor and serve runtime resolve `--kernel` once at startup and
    /// construct every lane/session through here, so the hot loops carry a
    /// statically-matched [`KernelKind`] tag instead of per-step dispatch.
    pub fn build_with_kernel<'c>(
        &self,
        cell: &'c dyn Cell,
        rng: &mut Pcg32,
        kernel: KernelKind,
    ) -> Box<dyn GradAlgo + 'c> {
        let plan = SparsityPlan::for_lane(*self, rng).with_kernel(kernel);
        <dyn GradAlgo>::build(*self, cell, &plan)
    }

    /// Frozen trains the readout only.
    pub fn trains_recurrent(&self) -> bool {
        !matches!(self, Method::Frozen)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::Arch;

    #[test]
    fn save_load_round_trip_is_bitwise_for_every_method() {
        // Drive each algorithm for a few steps, snapshot at an update
        // boundary, restore into a freshly built instance, then continue
        // both side by side: states and gradients must stay bit-identical.
        let methods = [
            Method::Bptt,
            Method::Rtrl,
            Method::SparseRtrl,
            Method::Snap(1),
            Method::Snap(2),
            Method::SnapTopK(2),
            Method::Uoro,
            Method::Rflo,
        ];
        for m in methods {
            let mut rng = Pcg32::seeded(0x5eed);
            let cell = Arch::Gru.build(6, 3, 0.5, &mut rng);
            let theta = cell.init_params(&mut rng);
            let p = cell.num_params();
            let mut build_rng_a = Pcg32::seeded(77);
            let mut build_rng_b = Pcg32::seeded(1234); // different UORO stream
            let mut a = m.build(cell.as_ref(), &mut build_rng_a);
            let mut b = m.build(cell.as_ref(), &mut build_rng_b);
            let mut g = vec![0.0f32; p];
            for t in 0..5 {
                let x: Vec<f32> = (0..3).map(|i| ((t * 3 + i) as f32).sin()).collect();
                a.step(&theta, &x);
                let c: Vec<f32> = (0..cell.hidden_size()).map(|i| (i as f32) - 2.0).collect();
                a.inject_loss(&c, &mut g);
                a.flush(&theta, &mut g); // update boundary (BPTT window drains)
            }
            let mut w = Writer::new();
            a.save_state(&mut w);
            let blob = w.into_bytes();
            b.load_state(&mut Reader::new(&blob)).unwrap_or_else(|e| {
                panic!("{}: load_state failed: {e}", m.name());
            });
            for t in 0..4 {
                let x: Vec<f32> = (0..3).map(|i| ((t * 7 + i) as f32).cos()).collect();
                let c: Vec<f32> = (0..cell.hidden_size()).map(|i| 0.5 - (i as f32)).collect();
                let mut ga = vec![0.0f32; p];
                let mut gb = vec![0.0f32; p];
                a.step(&theta, &x);
                a.inject_loss(&c, &mut ga);
                a.flush(&theta, &mut ga);
                b.step(&theta, &x);
                b.inject_loss(&c, &mut gb);
                b.flush(&theta, &mut gb);
                for (va, vb) in ga.iter().zip(&gb) {
                    assert_eq!(va.to_bits(), vb.to_bits(), "{} diverged after restore", m.name());
                }
                for (va, vb) in a.state().iter().zip(b.state()) {
                    assert_eq!(va.to_bits(), vb.to_bits(), "{} state diverged", m.name());
                }
            }
        }
    }

    #[test]
    fn load_state_rejects_the_wrong_method() {
        let mut rng = Pcg32::seeded(901);
        let cell = Arch::Gru.build(5, 2, 1.0, &mut rng);
        let snap = Method::Snap(1).build(cell.as_ref(), &mut rng);
        let mut w = Writer::new();
        snap.save_state(&mut w);
        let blob = w.into_bytes();
        let mut uoro = Method::Uoro.build(cell.as_ref(), &mut rng);
        let e = uoro.load_state(&mut Reader::new(&blob)).unwrap_err();
        assert!(e.to_string().contains("does not match"), "{e}");
    }

    #[test]
    fn factory_and_lane_wrapper_agree_bitwise_for_every_method() {
        // `Method::build` must be a pure delegation through the unified
        // `<dyn GradAlgo>::build` factory: same plan ⇒ same instance, same
        // RNG consumption (one split for UORO, none otherwise).
        let methods = [
            Method::Bptt,
            Method::Frozen,
            Method::Rtrl,
            Method::SparseRtrl,
            Method::Snap(2),
            Method::SnapTopK(2),
            Method::Uoro,
            Method::Rflo,
        ];
        for m in methods {
            let mut rng = Pcg32::seeded(0xfac);
            let cell = Arch::Gru.build(5, 3, 0.75, &mut rng);
            let theta = cell.init_params(&mut rng);
            let p = cell.num_params();
            let mut rng_a = Pcg32::seeded(42);
            let mut rng_b = Pcg32::seeded(42);
            let mut a = m.build(cell.as_ref(), &mut rng_a);
            let plan = SparsityPlan::for_lane(m, &mut rng_b);
            let mut b = <dyn GradAlgo>::build(m, cell.as_ref(), &plan);
            // The wrapper consumed exactly what the plan did.
            assert_eq!(rng_a.state_parts(), rng_b.state_parts(), "{}", m.name());
            let mut ga = vec![0.0f32; p];
            let mut gb = vec![0.0f32; p];
            for t in 0..3 {
                let x: Vec<f32> = (0..3).map(|i| ((t * 5 + i) as f32).sin()).collect();
                let c: Vec<f32> = (0..cell.hidden_size()).map(|i| (i as f32) - 1.5).collect();
                a.step(&theta, &x);
                a.inject_loss(&c, &mut ga);
                a.flush(&theta, &mut ga);
                b.step(&theta, &x);
                b.inject_loss(&c, &mut gb);
                b.flush(&theta, &mut gb);
            }
            for (va, vb) in ga.iter().zip(&gb) {
                assert_eq!(va.to_bits(), vb.to_bits(), "{} diverged", m.name());
            }
        }
    }

    #[test]
    fn method_parsing() {
        assert_eq!(Method::parse("bptt"), Some(Method::Bptt));
        assert_eq!(Method::parse("snap-1"), Some(Method::Snap(1)));
        assert_eq!(Method::parse("SnAp-3"), Some(Method::Snap(3)));
        assert_eq!(Method::parse("snap-0"), None);
        assert_eq!(Method::parse("uoro"), Some(Method::Uoro));
        assert_eq!(Method::parse("nope"), None);
        assert_eq!(Method::Snap(2).name(), "snap-2");
        assert_eq!(Method::parse("snap-topk-4"), Some(Method::SnapTopK(4)));
        assert_eq!(Method::SnapTopK(4).name(), "snap-topk-4");
    }
}
