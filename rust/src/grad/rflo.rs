//! RFLO — Random Feedback Local Online learning (Murray 2019; paper §4).
//!
//! "Amounts to accumulating I_t terms in equation 4 whilst ignoring the
//! product D_t·J_{t-1}": `J_t = I_t + λ·J_{t-1}` on the SnAp-1 pattern.
//! λ=1 is the paper's description; λ<1 (leaky accumulation, Murray's 1−1/τ)
//! is available as an ablation. Strictly more biased than SnAp-1 — it drops
//! even the diagonal dynamics term that SnAp-1 keeps (eq. 3).

use crate::cells::Cell;
use crate::errors::Result;
use crate::grad::{check_state_tag, state_tags, GradAlgo};
use crate::runtime::serde::{Reader, Writer};
use crate::sparse::coljac::ColJacobian;
use crate::sparse::immediate::ImmediateJac;

pub struct Rflo<'c> {
    cell: &'c dyn Cell,
    s: Vec<f32>,
    j: ColJacobian,
    i_jac: ImmediateJac,
    cache: crate::cells::Cache,
    lambda: f32,
    /// persistent scratch (never serialized): next-state and padded-dlds
    s_next: Vec<f32>,
    dlds: Vec<f32>,
    last_flops: u64,
}

impl<'c> Rflo<'c> {
    pub fn new(cell: &'c dyn Cell, lambda: f32) -> Self {
        let i_jac = cell.immediate_structure();
        let pattern = i_jac.pattern();
        let ss = cell.state_size();
        Rflo {
            cell,
            s: vec![0.0; ss],
            j: ColJacobian::from_pattern(&pattern),
            i_jac,
            cache: cell.make_cache(),
            lambda,
            s_next: vec![0.0; ss],
            dlds: vec![0.0; ss],
            last_flops: 0,
        }
    }
}

impl GradAlgo for Rflo<'_> {
    fn name(&self) -> String {
        if self.lambda == 1.0 {
            "rflo".into()
        } else {
            format!("rflo-l{:.2}", self.lambda)
        }
    }

    fn reset(&mut self) {
        self.s.iter_mut().for_each(|v| *v = 0.0);
        self.j.reset();
    }

    // audit: hot-path
    fn step(&mut self, theta: &[f32], x: &[f32]) {
        // Allocation-free: forward into the owned scratch, then swap.
        self.cell.forward(theta, &self.s, x, &mut self.cache, &mut self.s_next);
        std::mem::swap(&mut self.s, &mut self.s_next);
        self.cell.immediate(&self.cache, &mut self.i_jac);
        self.j.update_rflo(self.lambda, &self.i_jac);
        self.last_flops = 2 * self.i_jac.nnz() as u64;
    }

    fn hidden(&self) -> &[f32] {
        &self.s[..self.cell.hidden_size()]
    }

    fn state(&self) -> &[f32] {
        &self.s
    }

    // audit: hot-path
    fn inject_loss(&mut self, dl_dh: &[f32], g: &mut [f32]) {
        let ss = self.cell.state_size();
        if dl_dh.len() == ss {
            self.j.accumulate_grad(dl_dh, g);
        } else {
            // LSTM: pad [dl_dh ; 0] in the owned scratch (tail stays zero).
            self.dlds[..dl_dh.len()].copy_from_slice(dl_dh);
            self.j.accumulate_grad(&self.dlds, g);
        }
        self.last_flops += 2 * self.j.nnz() as u64;
    }

    fn flush(&mut self, _theta: &[f32], _g: &mut [f32]) {}

    fn tracking_flops_per_step(&self) -> u64 {
        self.last_flops
    }

    fn tracking_memory_floats(&self) -> usize {
        self.j.nnz()
    }

    fn save_state(&self, w: &mut Writer) {
        w.put_u8(state_tags::RFLO);
        w.put_f32(self.lambda);
        w.put_u64(self.j.structure_fingerprint());
        w.put_f32s(&self.s);
        w.put_f32s(self.j.vals());
    }

    fn load_state(&mut self, r: &mut Reader<'_>) -> Result<()> {
        check_state_tag(r.get_u8()?, state_tags::RFLO, &self.name())?;
        let lambda = r.get_f32()?;
        crate::ensure!(
            lambda.to_bits() == self.lambda.to_bits(),
            "RFLO λ mismatch: checkpoint {lambda} vs run {}",
            self.lambda
        );
        let fp = r.get_u64()?;
        let here = self.j.structure_fingerprint();
        crate::ensure!(
            fp == here,
            "RFLO influence-pattern fingerprint mismatch \
             (checkpoint {fp:#018x} vs rebuilt {here:#018x})"
        );
        let s = r.get_f32s()?;
        crate::ensure!(
            s.len() == self.s.len(),
            "RFLO state length mismatch: checkpoint {} vs run {}",
            s.len(),
            self.s.len()
        );
        let vals = r.get_f32s()?;
        crate::ensure!(
            vals.len() == self.j.nnz(),
            "RFLO influence nnz mismatch: checkpoint {} vs run {}",
            vals.len(),
            self.j.nnz()
        );
        self.s = s;
        self.j.vals_mut().copy_from_slice(&vals);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells::Arch;
    use crate::grad::snap::Snap;
    use crate::tensor::rng::Pcg32;

    #[test]
    fn single_step_equals_snap1() {
        // With zero prior influence, one step of RFLO and SnAp-1 both give
        // J = I, so their gradients coincide on the first step.
        let mut rng = Pcg32::seeded(900);
        let cell = Arch::Gru.build(6, 3, 0.5, &mut rng);
        let theta = cell.init_params(&mut rng);
        let x: Vec<f32> = (0..3).map(|_| rng.normal()).collect();
        let c: Vec<f32> = (0..6).map(|_| rng.normal()).collect();

        let mut rflo = Rflo::new(cell.as_ref(), 1.0);
        let mut snap = Snap::new(cell.as_ref(), 1);
        let mut g1 = vec![0.0f32; cell.num_params()];
        let mut g2 = vec![0.0f32; cell.num_params()];
        rflo.step(&theta, &x);
        rflo.inject_loss(&c, &mut g1);
        snap.step(&theta, &x);
        snap.inject_loss(&c, &mut g2);
        for (a, b) in g1.iter().zip(g2.iter()) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn multi_step_differs_from_snap1() {
        // After ≥2 steps SnAp-1's diagonal D term makes them diverge.
        let mut rng = Pcg32::seeded(901);
        let cell = Arch::Gru.build(6, 3, 0.5, &mut rng);
        let theta = cell.init_params(&mut rng);
        let mut rflo = Rflo::new(cell.as_ref(), 1.0);
        let mut snap = Snap::new(cell.as_ref(), 1);
        let mut g1 = vec![0.0f32; cell.num_params()];
        let mut g2 = vec![0.0f32; cell.num_params()];
        for t in 0..4 {
            let x: Vec<f32> = (0..3).map(|_| rng.normal()).collect();
            let c: Vec<f32> = (0..6).map(|_| (t as f32) - 1.0).collect();
            rflo.step(&theta, &x);
            rflo.inject_loss(&c, &mut g1);
            snap.step(&theta, &x);
            snap.inject_loss(&c, &mut g2);
        }
        let diff: f32 = g1.iter().zip(&g2).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 1e-4, "RFLO should differ from SnAp-1 after multiple steps");
    }

    #[test]
    fn memory_equals_param_count_for_gru() {
        let mut rng = Pcg32::seeded(902);
        let cell = Arch::Gru.build(8, 4, 0.5, &mut rng);
        let rflo = Rflo::new(cell.as_ref(), 1.0);
        assert_eq!(rflo.tracking_memory_floats(), cell.num_params());
    }

    #[test]
    fn leaky_variant_decays_influence() {
        let mut rng = Pcg32::seeded(903);
        let cell = Arch::Vanilla.build(4, 2, 1.0, &mut rng);
        let theta = cell.init_params(&mut rng);
        let mut r1 = Rflo::new(cell.as_ref(), 1.0);
        let mut r05 = Rflo::new(cell.as_ref(), 0.5);
        for _ in 0..10 {
            let x = vec![0.5, -0.5];
            r1.step(&theta, &x);
            r05.step(&theta, &x);
        }
        let n1: f32 = r1.j.to_dense().norm();
        let n05: f32 = r05.j.to_dense().norm();
        assert!(n05 < n1, "leaky RFLO should have smaller influence norm");
    }
}
