//! Build-time toolchain sniff for the AVX-512 kernel bodies.
//!
//! The crate's MSRV is 1.74, but the `_mm512_*` intrinsics and
//! `#[target_feature(enable = "avx512f")]` only stabilized in rustc 1.89.
//! Instead of raising the floor for one optional backend, this script asks
//! the compiler its version and emits `snap_avx512` when the AVX-512
//! surface is available; `sparse/simd.rs` gates the 512-bit bodies (and the
//! `have_avx512()` runtime sniff) on that cfg, so older toolchains still
//! build every other backend and `KernelChoice::Auto` simply never selects
//! a kernel the binary doesn't contain.
//!
//! No external crates (the build image is offline): the version is parsed
//! straight out of `rustc --version`.

use std::process::Command;

fn rustc_minor() -> Option<u32> {
    let rustc = std::env::var("RUSTC").unwrap_or_else(|_| "rustc".to_string());
    let out = Command::new(rustc).arg("--version").output().ok()?;
    let text = String::from_utf8(out.stdout).ok()?;
    // "rustc 1.89.0 (…)" — second whitespace-separated field, dot-split.
    let version = text.split_whitespace().nth(1)?;
    version.split('.').nth(1)?.parse().ok()
}

fn main() {
    println!("cargo:rerun-if-changed=build.rs");
    // Unknown-version fallback: no cfg, i.e. no AVX-512 bodies — the safe
    // direction (the scalar/AVX2/NEON backends cover every host).
    let minor = rustc_minor().unwrap_or(0);
    if minor >= 80 {
        // Declare the custom cfg so `unexpected_cfgs` (lint since 1.80)
        // stays quiet under `clippy -D warnings` whether or not it is set.
        println!("cargo::rustc-check-cfg=cfg(snap_avx512)");
    }
    if minor >= 89 {
        println!("cargo:rustc-cfg=snap_avx512");
    }
}
